//! Quickstart: profile two reference applications, match an unknown one,
//! and print the vote — the paper's whole loop in ~20 lines.
//!
//! Run with: `cargo run --release --example quickstart`

use mrtuner::prelude::*;

fn main() {
    mrtuner::util::logging::init();
    let grid = ConfigGrid::small(1);

    // Profiling phase: build the reference database (paper Fig. 4a).
    let mut sys = TuningSystem::new(SystemConfig::default());
    sys.profile_app(AppId::WordCount, &grid);
    sys.profile_app(AppId::TeraSort, &grid);
    println!("reference database: {} entries", sys.db.len());

    // Matching phase: who does Exim mainlog parsing behave like? (Fig. 4b)
    let outcome = sys.match_app(AppId::EximParse, &grid);
    for v in &outcome.votes {
        println!(
            "  {:28} -> {:10} ({:.1}%)",
            v.config.label(),
            v.best_app.map(|a| a.name()).unwrap_or("-"),
            v.best_similarity
        );
    }
    println!("tally: {:?}", outcome.tally);
    println!(
        "most similar application: {}",
        outcome.winner.map(|a| a.name()).unwrap_or("none")
    );
    assert_eq!(outcome.winner, Some(AppId::WordCount), "paper's headline result");
}
