//! Streaming online classification: anytime DTW matching over live CPU
//! streams.
//!
//! The paper's pipeline classifies a job only after its full CPU series is
//! captured — forfeiting most of the tuning benefit, since the answer
//! arrives when the job is done. This subsystem classifies a job *while it
//! is still running*: a [`session::StreamSession`] ingests CPU samples one
//! batch at a time and maintains an anytime top-k over the reference
//! database, declaring a [`session::StreamDecision`] as soon as the
//! evidence is safe under the configured [`session::DecisionPolicy`].
//!
//! The moving parts, bottom-up:
//!
//! * **Online preprocessing** — the paper's §3.1.1 chain (causal Chebyshev
//!   low-pass + min-max normalization) runs incrementally:
//!   [`crate::signal::chebyshev::SosState`] filters sample-by-sample
//!   (bit-identical to the batch filter) and
//!   [`crate::signal::normalize::OnlineMinMax`] tracks the growing
//!   prefix's extrema, whose monotone widening is what the bounds below
//!   exploit.
//! * **Monotone prefix lower bounds** — [`prefix_lb::prefix_lb`] bounds
//!   the *final* banded-DTW distance of the completed query to each
//!   reference from only the prefix, the reference's cached
//!   [`crate::index::Envelope`], and the shared
//!   [`crate::dtw::band_edges`] geometry. The bound is monotone
//!   non-decreasing as samples arrive and never exceeds the final
//!   distance (see the module docs for the proof sketch), so a candidate
//!   whose bound has grown past the current best can be culled for the
//!   rest of the stream.
//! * **Anytime ranking** — [`anytime::prefix_dtw`] runs the exact banded
//!   DP over the observed rows with early abandoning, giving each
//!   finalist a tight current distance (and the exact
//!   [`crate::dtw::banded::dtw_banded`] distance once the stream
//!   completes).
//! * **Sessions and multiplexing** — [`session::StreamSession`] holds one
//!   live stream's state; [`manager::SessionManager`] multiplexes many
//!   concurrent sessions behind the blocking server
//!   (`coordinator::server` commands `stream_open` / `stream_feed` /
//!   `stream_poll` / `stream_close`).
//!
//! Two guarantees anchor the design (pinned by `rust/tests/properties.rs`):
//! the prefix lower bound is monotone and admissible for streams up to the
//! pipeline's 512-sample resample cap — longer captures double a
//! decimation factor and rebuild the online state so sessions stay
//! incremental at any length — and a session fed to completion and
//! finalized returns exactly the neighbours of
//! `Matcher::match_app_indexed` on the full series — culling and early
//! exit accelerate the *anytime* answer, never the final one.

pub mod anytime;
pub mod manager;
pub mod prefix_lb;
pub mod session;

pub use manager::{SessionManager, SessionPoll};
pub use prefix_lb::FinalLen;
pub use session::{
    DecisionPolicy, StreamDecision, StreamSession, TopEntry, MAX_RETAINED, MAX_STREAM_LEN,
};

/// Per-session work counters; the streaming analogue of
/// [`crate::index::SearchStats`].
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct StreamStats {
    /// Samples ingested.
    pub samples: u64,
    /// Feed batches processed.
    pub batches: u64,
    /// Prefix lower-bound refreshes.
    pub lb_evals: u64,
    /// Prefix DPs run to the last observed row.
    pub dp_evals: u64,
    /// Prefix DPs abandoned early against the best-so-far cutoff.
    pub dp_abandoned: u64,
    /// Candidates culled for the rest of the stream.
    pub culled: u64,
}

impl StreamStats {
    /// Accumulate another session's counters into this one.
    pub fn merge(&mut self, other: &StreamStats) {
        self.samples += other.samples;
        self.batches += other.batches;
        self.lb_evals += other.lb_evals;
        self.dp_evals += other.dp_evals;
        self.dp_abandoned += other.dp_abandoned;
        self.culled += other.culled;
    }
}

impl std::fmt::Display for StreamStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "samples={} batches={} lb_evals={} dp[evals={} abandoned={}] culled={}",
            self.samples, self.batches, self.lb_evals, self.dp_evals, self.dp_abandoned, self.culled,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_merge_and_display() {
        let mut a = StreamStats {
            samples: 10,
            batches: 2,
            lb_evals: 5,
            dp_evals: 3,
            dp_abandoned: 1,
            culled: 4,
        };
        let b = a;
        a.merge(&b);
        assert_eq!(a.samples, 20);
        assert_eq!(a.culled, 8);
        assert!(a.to_string().contains("culled=8"), "{a}");
    }
}
