//! [`MrtunerClient`]: a reconnecting, pipelining protocol-v2 client for
//! the match service.
//!
//! * **Typed**: requests go out as [`Request`], replies come back as
//!   [`Response`] bodies — no JSON at call sites. Server-side failures
//!   surface as [`ClientError::Server`] with their [`ErrorCode`] intact.
//! * **Pipelining**: [`MrtunerClient::send`] writes a request and returns
//!   its id immediately; [`MrtunerClient::recv`] reads until that id's
//!   reply arrives, stashing any other reply it passes. A caller can
//!   write N requests back-to-back and collect the replies afterwards —
//!   one round trip instead of N. This is what the shard router uses to
//!   overlap fan-out across shards without threads.
//! * **Reconnecting**: the client remembers its address. A dead
//!   connection (the server drops peers idle past `CONN_IDLE`) is
//!   re-established transparently on the next send; [`MrtunerClient::call`]
//!   additionally replays the request once if the failure hit an
//!   [idempotent](Request::is_idempotent) request mid-flight. Stream
//!   *sessions* survive reconnects by design — they are addressed by id,
//!   not by connection — but non-idempotent stream mutations
//!   (`stream_feed`/`open`/`close`) are never auto-replayed, because the
//!   client cannot know whether the server applied them before the
//!   connection died.

use crate::protocol::{
    decode_reply, ErrorCode, KnnBatchBody, KnnBody, MatchBody, Request, Response, ServerError,
    ShardInfoBody, StatsBody, StreamCloseBody, StreamFeedBody, StreamOpenBody, StreamPollBody,
    StreamTunedBody,
};
use crate::simulator::job::JobConfig;
use crate::util::rng::Rng;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Capped exponential backoff with deterministic seeded jitter, used by
/// the client's reconnect loop so a dead server never triggers a tight
/// reconnect storm. The delay before retry `attempt` (0-based) is
/// `min(cap, base << attempt)`, jittered uniformly into its upper half
/// (`[delay/2, delay]`) so simultaneous clients decorrelate while the
/// sequence stays reproducible for a given seed.
#[derive(Debug, Clone)]
pub struct Backoff {
    base: Duration,
    cap: Duration,
    attempts: u32,
    rng: Rng,
}

impl Backoff {
    /// First retry delay.
    pub const DEFAULT_BASE: Duration = Duration::from_millis(5);
    /// Largest un-jittered delay.
    pub const DEFAULT_CAP: Duration = Duration::from_millis(200);
    /// Total connect attempts (1 initial + `DEFAULT_ATTEMPTS - 1` retries).
    pub const DEFAULT_ATTEMPTS: u32 = 3;

    /// Fully parameterized backoff schedule.
    pub fn new(base: Duration, cap: Duration, attempts: u32, seed: u64) -> Backoff {
        Backoff {
            base,
            cap,
            attempts: attempts.max(1),
            rng: Rng::new(seed),
        }
    }

    /// The default schedule with a caller-chosen jitter seed.
    pub fn from_seed(seed: u64) -> Backoff {
        Backoff::new(
            Backoff::DEFAULT_BASE,
            Backoff::DEFAULT_CAP,
            Backoff::DEFAULT_ATTEMPTS,
            seed,
        )
    }

    /// Total connect attempts the reconnect loop is bounded by.
    pub fn attempts(&self) -> u32 {
        self.attempts
    }

    /// The jittered delay before retry `attempt` (0-based).
    pub fn delay(&mut self, attempt: u32) -> Duration {
        let exp = self.base.saturating_mul(1u32 << attempt.min(16));
        let nanos = exp.min(self.cap).as_nanos() as u64;
        let half = nanos / 2;
        Duration::from_nanos(half + self.rng.below(half + 1))
    }
}

/// Why a client call failed.
#[derive(Debug)]
pub enum ClientError {
    /// The connection could not be (re)established, died mid-call, or the
    /// request was lost to a reconnect.
    Io(std::io::Error),
    /// The server answered something that is not a valid v2 reply.
    Wire(String),
    /// The server answered a structured error.
    Server(ServerError),
}

impl ClientError {
    /// The server's error code, when this is a structured server error.
    pub fn code(&self) -> Option<ErrorCode> {
        match self {
            ClientError::Server(e) => Some(e.code),
            _ => None,
        }
    }
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io: {e}"),
            ClientError::Wire(m) => write!(f, "wire: {m}"),
            ClientError::Server(e) => write!(f, "server: {e}"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> ClientError {
        ClientError::Io(e)
    }
}

struct Conn {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

/// Recognize an id-less legacy-shaped reject (`{"error":msg,"ok":false}`)
/// and lift it into a typed error. The code is reconstructed from the
/// message, since the legacy shape carries none.
fn legacy_reject(line: &str) -> Option<ServerError> {
    let v = crate::util::json::Json::parse(line).ok()?;
    if v.get("ok").and_then(crate::util::json::Json::as_bool) != Some(false) {
        return None;
    }
    let msg = v.get("error").and_then(crate::util::json::Json::as_str)?;
    let code = if msg.contains("too large") {
        ErrorCode::TooLarge
    } else {
        ErrorCode::BadRequest
    };
    Some(ServerError::new(code, msg))
}

/// A blocking protocol-v2 client (see module docs).
pub struct MrtunerClient {
    addr: String,
    conn: Option<Conn>,
    timeout: Option<Duration>,
    backoff: Backoff,
    next_id: u64,
    /// Connection generation; bumps on every reconnect so ids sent on a
    /// dead connection fail loudly instead of blocking forever.
    epoch: u64,
    /// Outstanding ids → the epoch they were written under.
    sent: BTreeMap<u64, u64>,
    /// Replies read while scanning for a different id.
    pending: BTreeMap<u64, Result<Response, ServerError>>,
}

impl MrtunerClient {
    /// Connect to `addr` (`host:port`). Fails fast if the server is
    /// unreachable; later disconnects are repaired on the next call.
    pub fn connect(addr: &str) -> Result<MrtunerClient, ClientError> {
        MrtunerClient::connect_opts(addr, None)
    }

    /// [`MrtunerClient::connect`] with a read timeout on replies.
    pub fn connect_timeout(addr: &str, timeout: Duration) -> Result<MrtunerClient, ClientError> {
        MrtunerClient::connect_opts(addr, Some(timeout))
    }

    fn connect_opts(addr: &str, timeout: Option<Duration>) -> Result<MrtunerClient, ClientError> {
        // The jitter seed is derived from the address (FNV-1a) so two
        // clients of different backends never share a jitter stream, while
        // the same client setup replays the same schedule.
        let seed = addr
            .bytes()
            .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
                (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3)
            });
        let mut client = MrtunerClient {
            addr: addr.to_string(),
            conn: None,
            timeout,
            backoff: Backoff::from_seed(seed),
            next_id: 0,
            epoch: 0,
            sent: BTreeMap::new(),
            pending: BTreeMap::new(),
        };
        client.ensure_connected()?;
        Ok(client)
    }

    /// The address this client (re)connects to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Replace the reconnect backoff schedule (tests pin the jitter seed;
    /// the router shortens the schedule for fast failover probes).
    pub fn set_backoff(&mut self, backoff: Backoff) {
        self.backoff = backoff;
    }

    /// Adjust the per-reply read timeout, effective immediately on the
    /// live connection and inherited by reconnects. The shard router's
    /// deadline budgeting uses this to cap each fan-out recv at the
    /// request's remaining budget.
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> Result<(), ClientError> {
        self.timeout = timeout;
        if let Some(conn) = self.conn.as_ref() {
            // The reader is a dup of the same socket, so one setsockopt
            // covers both halves.
            conn.writer.set_read_timeout(timeout)?;
        }
        Ok(())
    }

    fn ensure_connected(&mut self) -> Result<(), ClientError> {
        if self.conn.is_some() {
            return Ok(());
        }
        // Bounded by the backoff's attempt budget: each failed connect
        // sleeps the capped jittered backoff delay before the next try.
        let mut attempt = 0;
        loop {
            match TcpStream::connect(&self.addr) {
                Ok(stream) => {
                    let _ = stream.set_nodelay(true);
                    if let Some(t) = self.timeout {
                        stream.set_read_timeout(Some(t))?;
                    }
                    let writer = stream.try_clone()?;
                    self.conn = Some(Conn {
                        writer,
                        reader: BufReader::new(stream),
                    });
                    self.epoch += 1;
                    return Ok(());
                }
                Err(e) if attempt + 1 < self.backoff.attempts() => {
                    let delay = self.backoff.delay(attempt);
                    log::debug!(
                        "client {}: connect failed ({e}); retry {} in {delay:?}",
                        self.addr,
                        attempt + 1
                    );
                    std::thread::sleep(delay);
                    attempt += 1;
                }
                Err(e) => return Err(ClientError::Io(e)),
            }
        }
    }

    fn drop_conn(&mut self) {
        self.conn = None;
    }

    fn try_write(&mut self, line: &str) -> std::io::Result<()> {
        let conn = match self.conn.as_mut() {
            Some(conn) => conn,
            None => {
                let e = std::io::Error::new(std::io::ErrorKind::NotConnected, "not connected");
                return Err(e);
            }
        };
        conn.writer.write_all(line.as_bytes())?;
        conn.writer.write_all(b"\n")?;
        conn.writer.flush()
    }

    /// Write one request and return its id without waiting for the reply —
    /// the pipelining half. A failed write triggers one transparent
    /// reconnect + rewrite. This is safe even for non-idempotent requests:
    /// a write error means the line's newline never reached the kernel,
    /// and the server executes a line only once its newline arrives
    /// (unterminated tails are rejected at EOF, never applied).
    pub fn send(&mut self, req: &Request) -> Result<u64, ClientError> {
        self.send_traced(req, 0)
    }

    /// [`MrtunerClient::send`] carrying a trace span id in the envelope's
    /// optional `trace` field (0 = untraced, field omitted), so server-side
    /// spans can nest under a caller-side span. The shard router uses this
    /// to link each shard's request tree to its fan-out span.
    pub fn send_traced(&mut self, req: &Request, trace: u64) -> Result<u64, ClientError> {
        self.next_id += 1;
        let id = self.next_id;
        let line = req.to_v2_traced(id, trace).to_string();
        self.ensure_connected()?;
        if let Err(e) = self.try_write(&line) {
            log::debug!("client {}: write failed ({e}); reconnecting", self.addr);
            self.drop_conn();
            self.ensure_connected()?;
            self.try_write(&line)?;
        }
        self.sent.insert(id, self.epoch);
        Ok(id)
    }

    /// Abandon an in-flight request: it will never be `recv`'d, and its
    /// eventual reply (if any) is dropped on arrival instead of being
    /// stashed forever. Fan-out callers that abort early (the shard
    /// router, when one shard fails mid-fan) use this to keep the
    /// pending/sent maps bounded.
    pub fn forget(&mut self, id: u64) {
        self.sent.remove(&id);
        self.pending.remove(&id);
    }

    /// Read replies until `id`'s arrives (replies to other in-flight ids
    /// are stashed for their own `recv`; replies to forgotten or unknown
    /// ids are dropped). Errors if the id was never sent or was lost to a
    /// reconnect.
    pub fn recv(&mut self, id: u64) -> Result<Response, ClientError> {
        if let Some(r) = self.pending.remove(&id) {
            self.sent.remove(&id);
            return r.map_err(ClientError::Server);
        }
        match self.sent.get(&id).copied() {
            None => return Err(ClientError::Wire(format!("unknown request id {id}"))),
            Some(epoch) if epoch != self.epoch || self.conn.is_none() => {
                self.sent.remove(&id);
                return Err(ClientError::Io(std::io::Error::new(
                    std::io::ErrorKind::ConnectionReset,
                    format!("request {id} was lost to a reconnect"),
                )));
            }
            Some(_) => {}
        }
        loop {
            let mut line = String::new();
            let conn = self
                .conn
                .as_mut()
                .ok_or_else(|| ClientError::Wire("not connected".to_string()))?;
            match conn.reader.read_line(&mut line) {
                Ok(0) => {
                    self.drop_conn();
                    self.sent.remove(&id);
                    return Err(ClientError::Io(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "server closed the connection",
                    )));
                }
                Ok(_) => {}
                Err(e) => {
                    self.drop_conn();
                    self.sent.remove(&id);
                    return Err(ClientError::Io(e));
                }
            }
            let (rid, result) = match decode_reply(line.trim()) {
                Ok(decoded) => decoded,
                // The server rejects what it cannot parse far enough to
                // know the envelope (oversized lines, invalid UTF-8) in
                // the id-less legacy shape. It answers strictly in order,
                // so such a reject belongs to the oldest id still
                // outstanding on this connection.
                Err(wire_err) => match legacy_reject(line.trim()) {
                    Some(err) => {
                        let oldest = self
                            .sent
                            .iter()
                            .find(|&(_, &epoch)| epoch == self.epoch)
                            .map(|(&rid, _)| rid);
                        match oldest {
                            Some(rid) => (rid, Err(err)),
                            None => return Err(ClientError::Wire(wire_err)),
                        }
                    }
                    None => return Err(ClientError::Wire(wire_err)),
                },
            };
            let known = self.sent.remove(&rid).is_some();
            if rid == id {
                return result.map_err(ClientError::Server);
            }
            if known {
                self.pending.insert(rid, result);
            }
            // else: a reply to a forgotten id — dropped.
        }
    }

    /// One blocking round trip. If the connection dies mid-call and the
    /// request is [idempotent](Request::is_idempotent), it is replayed
    /// once on a fresh connection.
    pub fn call(&mut self, req: &Request) -> Result<Response, ClientError> {
        let id = self.send(req)?;
        match self.recv(id) {
            Err(ClientError::Io(e)) if req.is_idempotent() => {
                log::debug!(
                    "client {}: {} lost to {e}; replaying once",
                    self.addr,
                    req.type_name()
                );
                let id = self.send(req)?;
                self.recv(id)
            }
            other => other,
        }
    }

    fn unexpected(want: &str, got: &Response) -> ClientError {
        ClientError::Wire(format!(
            "expected {want} response, got {}",
            got.type_name()
        ))
    }

    // ---------- typed convenience wrappers ----------

    pub fn ping(&mut self) -> Result<(), ClientError> {
        match self.call(&Request::Ping)? {
            Response::Pong => Ok(()),
            other => Err(Self::unexpected("pong", &other)),
        }
    }

    pub fn stats(&mut self) -> Result<StatsBody, ClientError> {
        match self.call(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => Err(Self::unexpected("stats", &other)),
        }
    }

    pub fn apps(&mut self) -> Result<Vec<String>, ClientError> {
        match self.call(&Request::Apps)? {
            Response::Apps(a) => Ok(a),
            other => Err(Self::unexpected("apps", &other)),
        }
    }

    pub fn shard_info(&mut self) -> Result<ShardInfoBody, ClientError> {
        match self.call(&Request::ShardInfo)? {
            Response::ShardInfo(s) => Ok(s),
            other => Err(Self::unexpected("shard_info", &other)),
        }
    }

    /// The server's structured metrics snapshot (counters, latency
    /// quantiles, per-code protocol errors, per-shard fan-out).
    pub fn metrics(&mut self) -> Result<crate::util::json::Json, ClientError> {
        match self.call(&Request::Metrics)? {
            Response::Metrics(m) => Ok(m),
            other => Err(Self::unexpected("metrics", &other)),
        }
    }

    /// Snapshot the server's flight recorder: `{"spans", "dropped",
    /// "trace"}` where `trace` is a Chrome-loadable document of the last
    /// N finished spans. Empty when the server runs without a recorder.
    pub fn trace_dump(&mut self) -> Result<crate::util::json::Json, ClientError> {
        match self.call(&Request::TraceDump)? {
            Response::TraceDump(t) => Ok(t),
            other => Err(Self::unexpected("trace_dump", &other)),
        }
    }

    /// Exact k-NN over the server's database (or one config bucket).
    pub fn knn(
        &mut self,
        series: &[f64],
        k: usize,
        config: Option<&JobConfig>,
    ) -> Result<KnnBody, ClientError> {
        let req = Request::Knn {
            series: series.to_vec(),
            k,
            config: config.copied(),
            allow_partial: false,
        };
        match self.call(&req)? {
            Response::Knn(b) => Ok(b),
            other => Err(Self::unexpected("knn", &other)),
        }
    }

    /// Batched k-NN: many queries in one request, one entry-major pass
    /// server-side.
    pub fn knn_batch(
        &mut self,
        queries: &[Vec<f64>],
        k: usize,
        config: Option<&JobConfig>,
    ) -> Result<KnnBatchBody, ClientError> {
        let req = Request::KnnBatch {
            queries: queries.to_vec(),
            k,
            config: config.copied(),
            allow_partial: false,
        };
        match self.call(&req)? {
            Response::KnnBatch(b) => Ok(b),
            other => Err(Self::unexpected("knn_batch", &other)),
        }
    }

    /// The paper's matching phase: similarity of a raw capture against
    /// every reference of one configuration set.
    pub fn match_series(
        &mut self,
        series: &[f64],
        config: &JobConfig,
    ) -> Result<MatchBody, ClientError> {
        let req = Request::Match {
            series: series.to_vec(),
            config: *config,
        };
        match self.call(&req)? {
            Response::Match(b) => Ok(b),
            other => Err(Self::unexpected("match", &other)),
        }
    }

    /// Open a live classification session (scoped to `config`, or the
    /// whole database) with an optional known/maximum final length.
    pub fn stream_open(
        &mut self,
        config: Option<&JobConfig>,
        final_len: Option<usize>,
    ) -> Result<StreamOpenBody, ClientError> {
        self.stream_open_with(Request::StreamOpen {
            config: config.copied(),
            final_len,
            max_len: None,
            min_fraction: None,
            margin: None,
            min_samples: None,
        })
    }

    /// [`MrtunerClient::stream_open`] with full policy control (pass a
    /// [`Request::StreamOpen`]; any other variant is rejected).
    pub fn stream_open_with(&mut self, req: Request) -> Result<StreamOpenBody, ClientError> {
        if !matches!(req, Request::StreamOpen { .. }) {
            return Err(ClientError::Wire("stream_open_with needs a StreamOpen request".into()));
        }
        match self.call(&req)? {
            Response::StreamOpened(b) => Ok(b),
            other => Err(Self::unexpected("stream_opened", &other)),
        }
    }

    /// Feed raw CPU samples into a live session.
    pub fn stream_feed(
        &mut self,
        session: u64,
        samples: &[f64],
    ) -> Result<StreamFeedBody, ClientError> {
        self.stream_feed_progress(session, samples, None)
    }

    /// [`MrtunerClient::stream_feed`] reporting the producing job's
    /// completed fraction alongside the samples, so the server's
    /// final-length predictor can tighten the session's geometry.
    pub fn stream_feed_progress(
        &mut self,
        session: u64,
        samples: &[f64],
        progress: Option<f64>,
    ) -> Result<StreamFeedBody, ClientError> {
        let req = Request::StreamFeed {
            session,
            samples: samples.to_vec(),
            progress,
        };
        match self.call(&req)? {
            Response::StreamFed(b) => Ok(b),
            other => Err(Self::unexpected("stream_fed", &other)),
        }
    }

    /// A live session's anytime top-k.
    pub fn stream_poll(&mut self, session: u64, k: usize) -> Result<StreamPollBody, ClientError> {
        match self.call(&Request::StreamPoll { session, k })? {
            Response::StreamTop(b) => Ok(b),
            other => Err(Self::unexpected("stream_top", &other)),
        }
    }

    /// Close a session: the exact final answer over the whole capture.
    pub fn stream_close(&mut self, session: u64) -> Result<StreamCloseBody, ClientError> {
        match self.call(&Request::StreamClose { session })? {
            Response::StreamClosed(b) => Ok(b),
            other => Err(Self::unexpected("stream_closed", &other)),
        }
    }

    /// Tuning advice for a live session: its current match and the
    /// matched application's cached optimal configuration, if any.
    /// Read-only on the server, so it retries transparently.
    pub fn stream_tune(&mut self, session: u64) -> Result<StreamTunedBody, ClientError> {
        match self.call(&Request::StreamTune { session })? {
            Response::StreamTuned(b) => Ok(b),
            other => Err(Self::unexpected("stream_tuned", &other)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_deterministic_and_stays_in_bounds() {
        let base = Duration::from_millis(5);
        let cap = Duration::from_millis(200);
        let mut a = Backoff::new(base, cap, 5, 42);
        let mut b = Backoff::new(base, cap, 5, 42);
        for attempt in 0..10u32 {
            let da = a.delay(attempt);
            assert_eq!(da, b.delay(attempt), "seeded jitter is reproducible");
            let exp = base.saturating_mul(1u32 << attempt.min(16)).min(cap);
            assert!(da >= exp / 2 && da <= exp, "attempt {attempt}: {da:?} not in [{:?}, {exp:?}]", exp / 2);
        }
        // The cap holds even for absurd attempt counts (no shift overflow).
        assert!(a.delay(u32::MAX) <= cap);
        // Different seeds draw different jitter somewhere in the schedule.
        let mut c = Backoff::new(base, cap, 5, 43);
        let mut d = Backoff::new(base, cap, 5, 42);
        assert!((0..10).any(|i| c.delay(i) != d.delay(i)));
    }

    #[test]
    fn backoff_attempts_never_below_one() {
        assert_eq!(Backoff::new(Duration::ZERO, Duration::ZERO, 0, 1).attempts(), 1);
        assert_eq!(Backoff::from_seed(7).attempts(), Backoff::DEFAULT_ATTEMPTS);
    }
}
