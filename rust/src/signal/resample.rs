//! Linear resampling.
//!
//! Used to fold variable-length CPU series into the fixed shape buckets the
//! AOT artifacts are compiled for (series *longer* than the largest bucket
//! are linearly compressed; DTW inside a bucket still performs the nonlinear
//! alignment the paper relies on — §3.1.2 explains why resampling alone is
//! not a substitute for DTW, which is exactly how we use it).

/// Resample `xs` to `target` points by linear interpolation.
pub fn linear(xs: &[f64], target: usize) -> Vec<f64> {
    if target == 0 || xs.is_empty() {
        return Vec::new();
    }
    if xs.len() == 1 {
        return vec![xs[0]; target];
    }
    if target == 1 {
        return vec![xs[0]];
    }
    let step = (xs.len() - 1) as f64 / (target - 1) as f64;
    (0..target)
        .map(|i| {
            let pos = i as f64 * step;
            let lo = pos.floor() as usize;
            let hi = (lo + 1).min(xs.len() - 1);
            let frac = pos - lo as f64;
            xs[lo] * (1.0 - frac) + xs[hi] * frac
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_when_same_length() {
        let xs = [1.0, 3.0, 2.0, 5.0];
        let y = linear(&xs, 4);
        for (a, b) in xs.iter().zip(&y) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn endpoints_preserved() {
        let xs: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        for target in [2usize, 5, 36, 38, 100] {
            let y = linear(&xs, target);
            assert_eq!(y.len(), target);
            assert!((y[0] - xs[0]).abs() < 1e-12);
            assert!((y[target - 1] - xs[36]).abs() < 1e-12);
        }
    }

    #[test]
    fn upsampling_a_line_is_exact() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let y = linear(&xs, 7);
        for (i, v) in y.iter().enumerate() {
            assert!((v - i as f64 * 0.5).abs() < 1e-12);
        }
    }

    #[test]
    fn degenerate_inputs() {
        assert!(linear(&[], 5).is_empty());
        assert!(linear(&[1.0], 0).is_empty());
        assert_eq!(linear(&[2.5], 3), vec![2.5; 3]);
        assert_eq!(linear(&[1.0, 2.0], 1), vec![1.0]);
    }

    #[test]
    fn values_stay_within_input_range() {
        let xs = [0.2, 0.9, 0.1, 0.7, 0.4];
        let y = linear(&xs, 23);
        for v in y {
            assert!((0.1..=0.9).contains(&v));
        }
    }
}
