//! [`ShardRouter`]: compose per-config shard servers into one logical
//! reference database, plus [`RouterServer`], the TCP front-end that
//! speaks the same protocol the shards do.
//!
//! Multi-node serving splits the reference database across shard servers
//! (`mrtuner serve --shard-of CONFIGS`), each owning the entries of some
//! configuration sets. The router connects to every shard, learns what
//! each owns through the `shard_info` handshake, and assigns each shard a
//! **global index base** — the running sum of shard entry counts in
//! address order. The composed database is thereby *defined* as the
//! concatenation of the shard databases in that order, and a row's global
//! index is `shard.base + local index`.
//!
//! Fan-out uses the client's pipelining: one request is written to every
//! shard before any reply is read, so shard latencies overlap without
//! threads. Per-shard round trips land in
//! [`Metrics::record_shard_fanout`].
//!
//! **Determinism:** shards answer k-NN with exact per-entry distances (the
//! cascade's cutoffs only ever skip candidates that provably cannot enter
//! the top-k, and distances of returned rows are exact banded-DTW values —
//! independent of what else shares the database). Merging per-shard rows
//! in `(distance, global index)` order is therefore **bit-identical** to a
//! single-node `IndexedDb::knn_batch` over the union database built in the
//! same shard order — same neighbours, same distance bits, same order.
//! Pinned by `rust/tests/shard_router.rs`.
//!
//! Stream sessions are deliberately *not* routed: a session lives on one
//! shard (state and all); a feeder connects to the shard that owns its
//! configuration set. The router rejects `stream_*` with `bad_request`.

use super::metrics::Metrics;
use super::server::{serve_connection_lines, READ_TIMEOUT};
use crate::client::{ClientError, MrtunerClient};
use crate::dtw::corr::MATCH_THRESHOLD;
use crate::index::SearchStats;
use crate::protocol::{
    decode_line, encode_reply, ErrorCode, KnnBatchBody, KnnBody, MatchBody, Request, Response,
    ServerError, ShardInfoBody, StatsBody, Wire,
};
use crate::simulator::job::JobConfig;
use crate::trace::{Span, TraceHandle};
use crate::util::json::Json;
use crate::util::pool::ThreadPool;
use anyhow::Result;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Consecutive failures that trip a replica's circuit breaker open.
pub const BREAKER_THRESHOLD: u32 = 3;
/// Admission attempts skipped while open before a half-open probe.
pub const BREAKER_COOLDOWN: u32 = 4;

/// Circuit-breaker state of one replica backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: taking traffic.
    Closed,
    /// Tripped: skipped until the cooldown admits a probe.
    Open,
    /// One probe in flight; its outcome closes or re-trips the breaker.
    HalfOpen,
}

/// Per-replica consecutive-failure circuit breaker with half-open
/// probing. Deterministic by construction: the open cooldown is counted
/// in admission *attempts*, not wall time, so a scripted fault schedule
/// walks the same state trajectory on every run.
#[derive(Debug, Clone)]
pub struct Breaker {
    state: BreakerState,
    failures: u32,
    cooldown: u32,
}

impl Breaker {
    pub fn new() -> Breaker {
        Breaker {
            state: BreakerState::Closed,
            failures: 0,
            cooldown: 0,
        }
    }

    /// Current state (observability/tests).
    pub fn state(&self) -> BreakerState {
        self.state
    }

    /// May this replica take traffic now? Returns `(admitted, probe)`:
    /// an open breaker counts the attempt against its cooldown and, at
    /// zero, admits exactly one half-open probe (`probe = true`).
    pub fn try_admit(&mut self) -> (bool, bool) {
        match self.state {
            BreakerState::Closed | BreakerState::HalfOpen => (true, false),
            BreakerState::Open => {
                self.cooldown = self.cooldown.saturating_sub(1);
                if self.cooldown == 0 {
                    self.state = BreakerState::HalfOpen;
                    (true, true)
                } else {
                    (false, false)
                }
            }
        }
    }

    /// A request on this replica succeeded: close and reset.
    pub fn record_success(&mut self) {
        self.state = BreakerState::Closed;
        self.failures = 0;
    }

    /// A request on this replica failed; returns `true` when this
    /// failure tripped the breaker open (callers count trips). A failed
    /// half-open probe re-trips immediately.
    pub fn record_failure(&mut self) -> bool {
        self.failures = self.failures.saturating_add(1);
        let trip = match self.state {
            BreakerState::HalfOpen => true,
            BreakerState::Closed => self.failures >= BREAKER_THRESHOLD,
            BreakerState::Open => false,
        };
        if trip {
            self.state = BreakerState::Open;
            self.cooldown = BREAKER_COOLDOWN;
        }
        trip
    }
}

impl Default for Breaker {
    fn default() -> Breaker {
        Breaker::new()
    }
}

/// One replica backend of a shard slot: its address, (lazily) connected
/// client, and circuit-breaker health.
struct Replica {
    addr: String,
    /// `None` until first activated, and again after a transport failure
    /// (a failed stream is in an unknown state — reconnect + re-handshake
    /// before trusting it again).
    client: Option<MrtunerClient>,
    breaker: Breaker,
}

/// One shard slot: the replica set serving one partition of the global
/// index space, plus what the `shard_info` handshake reported it owns.
pub struct Shard {
    /// Global index base: the sum of entry counts of all earlier shards.
    pub base: usize,
    /// Entries this shard owns.
    pub entries: usize,
    /// Applications present on this shard.
    pub apps: Vec<String>,
    /// Configuration-set labels this shard owns.
    pub configs: Vec<String>,
    /// Replica backends, in failover order.
    replicas: Vec<Replica>,
    /// Index of the replica currently serving traffic.
    active: usize,
}

impl Shard {
    /// Address of the replica currently serving this slot's traffic.
    pub fn addr(&self) -> &str {
        &self.replicas[self.active].addr
    }

    /// All replica addresses, in failover order.
    pub fn replica_addrs(&self) -> Vec<&str> {
        self.replicas.iter().map(|r| r.addr.as_str()).collect()
    }

    /// Index of the active replica.
    pub fn active_replica(&self) -> usize {
        self.active
    }

    /// Circuit-breaker states per replica (observability/tests).
    pub fn breaker_states(&self) -> Vec<BreakerState> {
        self.replicas.iter().map(|r| r.breaker.state()).collect()
    }
}

/// A per-request time budget derived from the v2 envelope's optional
/// `deadline_ms`, measured on the router's trace clock (live even for a
/// disabled tracer). `None` deadline = unbounded — exactly the
/// pre-deadline behavior.
#[derive(Debug, Clone, Copy, Default)]
struct Budget {
    deadline_ns: Option<u64>,
}

/// Attempts stop subdividing the budget below this: the tail is spent
/// whole, so a stuck fleet reaches `deadline_exceeded` instead of
/// Zeno-ing through ever-smaller socket waits.
const BUDGET_FLOOR: Duration = Duration::from_millis(10);

impl Budget {
    fn none() -> Budget {
        Budget { deadline_ns: None }
    }

    fn start(tracer: &TraceHandle, deadline_ms: Option<u64>) -> Budget {
        Budget {
            deadline_ns: deadline_ms
                .map(|ms| tracer.now_ns().saturating_add(ms.saturating_mul(1_000_000))),
        }
    }

    /// Remaining budget (`None` = unbounded).
    fn remaining(&self, tracer: &TraceHandle) -> Option<Duration> {
        self.deadline_ns
            .map(|d| Duration::from_nanos(d.saturating_sub(tracer.now_ns())))
    }

    fn expired(&self, tracer: &TraceHandle) -> bool {
        matches!(self.remaining(tracer), Some(r) if r < Duration::from_millis(1))
    }
}

/// Send-phase outcome for one fan-out slot.
enum Sent {
    /// Request in flight on the active replica.
    Flight { id: u64, t0: u64 },
    /// The active replica failed (or was inadmissible) at send time;
    /// recovery runs in the settle phase.
    NeedsRecovery(ClientError),
}

/// The typed error a spent budget surfaces as.
fn deadline_err() -> ClientError {
    ClientError::Server(ServerError::new(
        ErrorCode::DeadlineExceeded,
        "request deadline expired during fan-out",
    ))
}

/// Routes `knn` / `knn_batch` / `match` over a fixed set of shard slots
/// (see module docs for the determinism contract), failing over between
/// a slot's replicas on transport errors.
pub struct ShardRouter {
    shards: Vec<Shard>,
    metrics: Arc<Metrics>,
    /// Span sink + clock for fan-out tracing; each per-shard round trip
    /// gets a child span whose id rides the envelope's `trace` field, so
    /// shard-side request trees nest under it. Disabled by default.
    tracer: TraceHandle,
    /// The in-flight request's deadline budget (set by routed dispatch;
    /// `none` for budget-less requests and direct helper calls).
    budget: Budget,
}

/// Map a shard-call failure onto the routed error surface: structured
/// shard answers keep their code; transport failures become
/// `shard_unavailable`.
fn shard_err(addr: &str, e: ClientError) -> ClientError {
    match e {
        ClientError::Server(se) => ClientError::Server(se),
        other => ClientError::Server(ServerError::new(
            ErrorCode::ShardUnavailable,
            format!("shard {addr}: {other}"),
        )),
    }
}

/// Read timeout on every shard connection. A shard that stops answering
/// without closing its socket must not wedge the router (routed dispatch
/// serializes on one lock): recv fails after this long and surfaces as
/// `shard_unavailable`. Generous next to real search latencies (ms).
pub const SHARD_REPLY_TIMEOUT: Duration = Duration::from_secs(30);

impl ShardRouter {
    /// Connect to every shard (in the given order — it defines the global
    /// index space) and run the `shard_info` handshake. One replica per
    /// slot; see [`ShardRouter::connect_groups`] for replica sets.
    pub fn connect(addrs: &[String], metrics: Arc<Metrics>) -> Result<ShardRouter, ClientError> {
        let groups: Vec<Vec<String>> = addrs.iter().map(|a| vec![a.clone()]).collect();
        ShardRouter::connect_groups(&groups, metrics)
    }

    /// Connect one replica per shard slot (slot order defines the global
    /// index space). Within a slot, replicas are tried in order; the
    /// first that connects and answers the `shard_info` handshake becomes
    /// active, the rest stay cold standbys that failover connects (and
    /// geometry-verifies) on demand. A slot where no replica answers is a
    /// startup error — degradation is a per-request decision, not a
    /// topology one.
    pub fn connect_groups(
        groups: &[Vec<String>],
        metrics: Arc<Metrics>,
    ) -> Result<ShardRouter, ClientError> {
        let mut shards = Vec::with_capacity(groups.len());
        let mut base = 0usize;
        for group in groups {
            if group.is_empty() {
                return Err(ClientError::Wire("empty replica group".to_string()));
            }
            let mut found: Option<(usize, MrtunerClient, ShardInfoBody)> = None;
            let mut last: Option<ClientError> = None;
            // Each replica is tried exactly once at startup — bounded by
            // the group itself, not a retry policy.
            // lint: allow(bounded-retry)
            for (ri, addr) in group.iter().enumerate() {
                let attempt = MrtunerClient::connect_timeout(addr, SHARD_REPLY_TIMEOUT)
                    .and_then(|mut client| client.shard_info().map(|info| (client, info)));
                match attempt {
                    Ok((client, info)) => {
                        found = Some((ri, client, info));
                        break;
                    }
                    Err(e) => {
                        log::warn!("router: replica {addr} unavailable at startup: {e}");
                        last = Some(e);
                    }
                }
            }
            let Some((active, client, info)) = found else {
                let e = last.unwrap_or_else(|| {
                    ClientError::Wire("no replica answered".to_string())
                });
                return Err(shard_err(&group.join(","), e));
            };
            log::info!(
                "router: shard {} owns {} entries across {} config sets ({} replicas)",
                group[active],
                info.entries,
                info.configs.len(),
                group.len(),
            );
            let mut replicas: Vec<Replica> = group
                .iter()
                .map(|addr| Replica {
                    addr: addr.clone(),
                    client: None,
                    breaker: Breaker::new(),
                })
                .collect();
            replicas[active].client = Some(client);
            let entries = info.entries;
            shards.push(Shard {
                base,
                entries,
                apps: info.apps,
                configs: info.configs,
                replicas,
                active,
            });
            base += entries;
        }
        Ok(ShardRouter {
            shards,
            metrics,
            tracer: TraceHandle::disabled(),
            budget: Budget::none(),
        })
    }

    /// Attach a tracer (builder-style; the default router is untraced).
    pub fn with_tracer(mut self, tracer: TraceHandle) -> ShardRouter {
        self.tracer = tracer;
        self
    }

    /// The router's trace handle.
    pub fn tracer(&self) -> &TraceHandle {
        &self.tracer
    }

    /// The connected shards, in global-index order.
    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    /// Entries across all shards (the union database size).
    pub fn total_entries(&self) -> usize {
        self.shards.iter().map(|s| s.entries).sum()
    }

    /// The router's metrics registry (shared with its front-end server).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Union of shard applications, sorted and deduplicated.
    pub fn apps(&self) -> Vec<String> {
        let mut apps: Vec<String> = self
            .shards
            .iter()
            .flat_map(|s| s.apps.iter().cloned())
            .collect();
        apps.sort();
        apps.dedup();
        apps
    }

    /// Aggregate `shard_info` over the composed database.
    pub fn aggregate_info(&self) -> ShardInfoBody {
        let mut configs: Vec<String> = self
            .shards
            .iter()
            .flat_map(|s| s.configs.iter().cloned())
            .collect();
        configs.sort();
        configs.dedup();
        ShardInfoBody {
            entries: self.total_entries(),
            apps: self.apps(),
            configs,
            sessions: Vec::new(),
        }
    }

    /// Shard positions that own `label` (usually exactly one under
    /// `--shard-of` partitioning; all claimants are consulted so overlap
    /// degrades to correct-but-wider fan-out, never to missed entries).
    fn owners(&self, label: &str) -> Vec<usize> {
        self.shards
            .iter()
            .enumerate()
            .filter(|(_, s)| s.configs.iter().any(|c| c == label))
            .map(|(si, _)| si)
            .collect()
    }

    /// The socket wait for one shard attempt: the shard reply timeout,
    /// capped against the request budget. Each attempt gets half the
    /// remaining budget (a stuck replica must leave time to fail over to
    /// a standby) until the remainder drops under [`BUDGET_FLOOR`], after
    /// which the tail is spent whole so expiry is actually reached.
    fn attempt_timeout(&self) -> Result<Duration, ClientError> {
        match self.budget.remaining(&self.tracer) {
            None => Ok(SHARD_REPLY_TIMEOUT),
            Some(rem) if rem < Duration::from_millis(1) => Err(deadline_err()),
            Some(rem) => {
                let per = if rem <= BUDGET_FLOOR { rem } else { rem / 2 };
                Ok(per.min(SHARD_REPLY_TIMEOUT))
            }
        }
    }

    fn budget_expired(&self) -> bool {
        self.budget.expired(&self.tracer)
    }

    /// Mutable client of the active replica. Invariant: failure paths
    /// either switch `active` to a freshly handshaken replica or drop the
    /// whole request, so the active replica always holds a client.
    fn active_client(&mut self, si: usize) -> &mut MrtunerClient {
        let a = self.shards[si].active;
        // lint: allow(no-panic) — active replica is connected by construction
        self.shards[si].replicas[a].client.as_mut().expect("active replica is connected")
    }

    /// Breaker-gate replica `ri` of shard `si`, counting admitted
    /// half-open probes.
    fn try_admit_replica(&mut self, si: usize, ri: usize) -> bool {
        let (admitted, probe) = self.shards[si].replicas[ri].breaker.try_admit();
        if probe {
            self.metrics.inc_circuit_probe();
        }
        admitted
    }

    /// The active replica answered: close its breaker.
    fn ok_active(&mut self, si: usize) {
        let a = self.shards[si].active;
        self.shards[si].replicas[a].breaker.record_success();
    }

    fn fail_active(&mut self, si: usize) {
        let a = self.shards[si].active;
        self.fail_replica(si, a);
    }

    /// Record a transport failure on a replica: drop its client (a failed
    /// stream is in an unknown state; the next activation reconnects and
    /// re-handshakes) and trip its breaker bookkeeping.
    fn fail_replica(&mut self, si: usize, ri: usize) {
        let rep = &mut self.shards[si].replicas[ri];
        rep.client = None;
        if rep.breaker.record_failure() {
            log::warn!("router: circuit opened for replica {} of shard {si}", rep.addr);
            self.metrics.inc_circuit_open();
        }
    }

    /// Connect (if cold) and handshake replica `ri` of shard `si`, verify
    /// it serves the same shard geometry as the slot was connected with,
    /// and make it the active replica. Structured handshake refusals are
    /// remapped to transport-shaped errors so a `Server` error escaping
    /// the failover path can only ever be the *request's* answer.
    fn activate_replica(&mut self, si: usize, ri: usize) -> Result<(), ClientError> {
        let timeout = self.attempt_timeout()?;
        let (want_entries, want_apps, want_configs) = {
            let s = &self.shards[si];
            (s.entries, s.apps.clone(), s.configs.clone())
        };
        let rep = &mut self.shards[si].replicas[ri];
        let addr = rep.addr.clone();
        if rep.client.is_none() {
            rep.client = Some(MrtunerClient::connect_timeout(&addr, SHARD_REPLY_TIMEOUT)?);
        }
        let Some(client) = rep.client.as_mut() else {
            return Err(ClientError::Wire(format!("replica {addr} lost its connection")));
        };
        client.set_read_timeout(Some(timeout))?;
        let info = match client.shard_info() {
            Ok(info) => info,
            Err(ClientError::Server(se)) => {
                return Err(ClientError::Wire(format!(
                    "replica {addr} refused the handshake: {se}"
                )))
            }
            Err(e) => return Err(e),
        };
        if info.entries != want_entries || info.apps != want_apps || info.configs != want_configs {
            return Err(ClientError::Wire(format!(
                "replica {addr} serves a different shard geometry \
                 ({} entries vs {want_entries})",
                info.entries,
            )));
        }
        self.shards[si].active = ri;
        Ok(())
    }

    /// Receive one in-flight reply with the socket wait capped by the
    /// request budget; an exhausted budget surfaces as the typed
    /// `deadline_exceeded` error instead of a transport failure.
    fn recv_budgeted(&mut self, si: usize, id: u64) -> Result<Response, ClientError> {
        let timeout = match self.attempt_timeout() {
            Ok(t) => t,
            Err(e) => {
                self.active_client(si).forget(id);
                return Err(e);
            }
        };
        self.active_client(si).set_read_timeout(Some(timeout))?;
        match self.active_client(si).recv(id) {
            Err(e) if self.budget_expired() => {
                log::debug!("router: shard {si} recv outlived the deadline ({e})");
                Err(deadline_err())
            }
            other => other,
        }
    }

    /// Full round trip on the active replica under the current budget.
    fn roundtrip(&mut self, si: usize, req: &Request, span: &Span) -> Result<Response, ClientError> {
        let wire = self.tracer.wire_trace(span);
        let id = self.active_client(si).send_traced(req, wire)?;
        self.recv_budgeted(si, id)
    }

    /// The active replica failed hard: rotate through the slot's other
    /// replicas (breaker-gated, each tried at most once, active last as a
    /// fresh-reconnect last resort), re-handshake + geometry-verify the
    /// candidate, and run the full round trip there. A structured reply
    /// from a replica is a healthy shard answering — passed through,
    /// never failed over around.
    fn failover_roundtrip(
        &mut self,
        si: usize,
        req: &Request,
        parent: &Span,
        mut last: ClientError,
    ) -> Result<Response, ClientError> {
        let n = self.shards[si].replicas.len();
        let start = self.shards[si].active;
        for attempt in 1..=n {
            if self.budget_expired() {
                return Err(deadline_err());
            }
            let ri = (start + attempt) % n;
            if !self.try_admit_replica(si, ri) {
                continue;
            }
            let addr = self.shards[si].replicas[ri].addr.clone();
            let span = parent.child("failover");
            span.event("replica", ri as u64);
            if span.active() {
                span.note("addr", &addr);
            }
            let t0 = self.tracer.now_ns();
            let result = self
                .activate_replica(si, ri)
                .and_then(|()| self.roundtrip(si, req, &span));
            match result {
                Ok(resp) => {
                    log::info!("router: shard {si} failed over to replica {addr}");
                    self.ok_active(si);
                    self.metrics.inc_shard_failover();
                    self.metrics
                        .record_shard_fanout(si, self.tracer.elapsed_secs(t0));
                    return Ok(resp);
                }
                // `activate_replica` remaps handshake refusals, so this is
                // the routed request's own structured answer: the replica
                // is healthy, surface the shard's code (and count the
                // failover that got us a live backend).
                Err(ClientError::Server(se)) => {
                    self.ok_active(si);
                    if se.code != ErrorCode::DeadlineExceeded {
                        self.metrics.inc_shard_failover();
                    }
                    return Err(ClientError::Server(se));
                }
                Err(e) => {
                    log::debug!("router: shard {si} replica {addr} failed during failover: {e}");
                    self.fail_replica(si, ri);
                    last = e;
                }
            }
        }
        // Raw (unwrapped) so `fan_partial` can still tell transport
        // failures apart from structured shard answers when degrading.
        Err(last)
    }

    /// Resolve one shard's fan-out slot: receive the in-flight reply
    /// (with one in-place replay on the same replica), or run failover
    /// recovery when the replica already failed at send time.
    fn settle(
        &mut self,
        si: usize,
        state: Sent,
        req: &Request,
        span: &Span,
    ) -> Result<Response, ClientError> {
        let (id, t0) = match state {
            Sent::Flight { id, t0 } => (id, t0),
            Sent::NeedsRecovery(e) => return self.failover_roundtrip(si, req, span, e),
        };
        match self.recv_budgeted(si, id) {
            Ok(resp) => {
                self.ok_active(si);
                self.metrics
                    .record_shard_fanout(si, self.tracer.elapsed_secs(t0));
                Ok(resp)
            }
            // A structured error is a healthy shard answering "no": pass
            // the shard's own code through untranslated (that includes a
            // spent budget surfacing as deadline_exceeded).
            Err(ClientError::Server(se)) => {
                if se.code != ErrorCode::DeadlineExceeded {
                    self.ok_active(si);
                }
                Err(ClientError::Server(se))
            }
            // Shards drop connections idle past their CONN_IDLE; the dead
            // socket usually swallows the write and only recv notices.
            // Every routed request is idempotent (streams are not routed),
            // so replay once on a fresh connection to the same replica
            // before failing over to a standby.
            Err(ClientError::Io(first)) if req.is_idempotent() && !self.budget_expired() => {
                self.active_client(si).forget(id);
                log::debug!("router: shard {si} recv failed ({first}); replaying once");
                let rspan = span.child("retry");
                rspan.event("shard", si as u64);
                self.metrics.inc_shard_retry();
                // Replay under the same sampling fate as the original
                // send, so a retried request cannot half-appear in the
                // stitched trace.
                match self.roundtrip(si, req, &rspan) {
                    Ok(resp) => {
                        self.ok_active(si);
                        self.metrics
                            .record_shard_fanout(si, self.tracer.elapsed_secs(t0));
                        Ok(resp)
                    }
                    Err(ClientError::Server(se)) => {
                        if se.code != ErrorCode::DeadlineExceeded {
                            self.ok_active(si);
                        }
                        Err(ClientError::Server(se))
                    }
                    Err(e) => {
                        drop(rspan);
                        self.fail_active(si);
                        self.failover_roundtrip(si, req, span, e)
                    }
                }
            }
            Err(e) => {
                self.active_client(si).forget(id);
                if self.budget_expired() {
                    return Err(deadline_err());
                }
                self.fail_active(si);
                self.failover_roundtrip(si, req, span, e)
            }
        }
    }

    /// Fan one request to `targets` (pipelined: all sends, then all
    /// settles), returning each shard's reply in target order. Each shard
    /// gets a child span of `parent` covering its whole round trip; the
    /// span's id is stamped into the request envelope's `trace` field so
    /// the shard's own request tree nests under it. All-or-nothing: any
    /// shard slot whose recovery fails drops the whole fan-out (in-flight
    /// ids are [`MrtunerClient::forget`]-gotten so stray replies cannot
    /// accumulate in client buffers across shard flaps).
    fn fan(
        &mut self,
        targets: &[usize],
        req: &Request,
        parent: &Span,
    ) -> Result<Vec<Response>, ClientError> {
        let (replies, _degraded) = self.fan_partial(targets, req, parent, false)?;
        Ok(replies.into_iter().flatten().collect())
    }

    /// [`ShardRouter::fan`], optionally degrading: with `allow_partial`,
    /// a shard slot whose recovery fails yields `None` plus its slot id
    /// in the degraded list instead of failing the whole fan-out. A spent
    /// deadline still fails the request (a partial answer you waited too
    /// long for helps nobody), as does a structured shard error (a
    /// healthy shard refusing is an answer, not an outage).
    fn fan_partial(
        &mut self,
        targets: &[usize],
        req: &Request,
        parent: &Span,
        allow_partial: bool,
    ) -> Result<(Vec<Option<Response>>, Vec<usize>), ClientError> {
        let mut sent: Vec<(usize, Sent, Span)> = Vec::with_capacity(targets.len());
        for &si in targets {
            let span = parent.child("shard");
            span.event("shard", si as u64);
            let active = self.shards[si].active;
            let connected = self.shards[si].replicas[active].client.is_some();
            // The envelope's `trace` field carries the sampling fate, not
            // just the span id: a recording span sends its id (shard tree
            // nests under it), a sampled-out fan-out sends the
            // TRACE_SAMPLED_OUT sentinel (shard records nothing), an
            // untraced router sends 0 (shard applies its own policy). This
            // is what keeps router and shards sampling the *same* requests.
            let state = if !self.try_admit_replica(si, active) {
                Sent::NeedsRecovery(ClientError::Wire(format!(
                    "active replica {} has an open circuit",
                    self.shards[si].replicas[active].addr,
                )))
            } else if !connected {
                // A previous recovery failed wholesale; reconnect through
                // the failover path rather than inline in the send fan.
                Sent::NeedsRecovery(ClientError::Wire(format!(
                    "active replica {} is disconnected",
                    self.shards[si].replicas[active].addr,
                )))
            } else {
                if span.active() {
                    span.note("addr", &self.shards[si].replicas[active].addr.clone());
                }
                let t0 = self.tracer.now_ns();
                let wire = self.tracer.wire_trace(&span);
                match self.active_client(si).send_traced(req, wire) {
                    Ok(id) => Sent::Flight { id, t0 },
                    Err(e) => {
                        self.fail_active(si);
                        Sent::NeedsRecovery(e)
                    }
                }
            };
            sent.push((si, state, span));
        }
        let mut replies: Vec<Option<Response>> = Vec::with_capacity(sent.len());
        let mut degraded: Vec<usize> = Vec::new();
        let mut failed: Option<ClientError> = None;
        for (si, state, span) in sent {
            if failed.is_some() {
                if let Sent::Flight { id, .. } = state {
                    self.active_client(si).forget(id);
                }
                continue;
            }
            match self.settle(si, state, req, &span) {
                Ok(resp) => replies.push(Some(resp)),
                Err(ClientError::Server(se)) if se.code == ErrorCode::DeadlineExceeded => {
                    failed = Some(ClientError::Server(se));
                }
                Err(ClientError::Server(se)) => failed = Some(ClientError::Server(se)),
                Err(e) if allow_partial => {
                    log::warn!("router: degrading around shard {si}: {e}");
                    span.event("degraded", 1);
                    self.metrics.inc_degraded_shard();
                    degraded.push(si);
                    replies.push(None);
                }
                Err(e) => {
                    let addr = self.shards[si].addr().to_string();
                    failed = Some(shard_err(&addr, e));
                }
            }
            // `span` drops here: the per-shard span closes at reply merge.
        }
        match failed {
            Some(e) => Err(e),
            None => Ok((replies, degraded)),
        }
    }

    /// Merge per-shard k-NN rows for one query: rebase local indices to
    /// global, then keep the k smallest under the engine's deterministic
    /// `(distance, index)` order.
    fn merge_knn(&self, targets: &[usize], per_shard: Vec<&KnnBody>, k: usize) -> KnnBody {
        let mut rows = Vec::new();
        let mut stats = SearchStats::default();
        for (&si, body) in targets.iter().zip(&per_shard) {
            let base = self.shards[si].base;
            for r in &body.neighbors {
                let mut r = r.clone();
                r.index += base;
                rows.push(r);
            }
            stats.merge(&body.stats);
        }
        rows.sort_by(|a, b| {
            (a.distance, a.index)
                .partial_cmp(&(b.distance, b.index))
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        rows.truncate(k);
        KnnBody {
            neighbors: rows,
            stats,
            degraded: vec![],
        }
    }

    /// Routed batched k-NN from an already-decoded [`Request::KnnBatch`]
    /// — the front-end's hot path fans the request it parsed without
    /// re-cloning megabyte-scale payloads. Bit-identical to a single-node
    /// `IndexedDb::knn_batch` over the union database. Per-shard round
    /// trips become child spans of `parent` (pass [`Span::none`] when
    /// untraced).
    pub fn route_knn_batch(
        &mut self,
        req: &Request,
        parent: &Span,
    ) -> Result<KnnBatchBody, ClientError> {
        let (nqueries, k, config, allow_partial) = match req {
            Request::KnnBatch {
                queries,
                k,
                config,
                allow_partial,
            } => (queries.len(), *k, config.as_ref(), *allow_partial),
            _ => {
                return Err(ClientError::Wire(
                    "route_knn_batch needs a KnnBatch request".to_string(),
                ))
            }
        };
        let targets: Vec<usize> = match config {
            Some(cfg) => self.owners(&cfg.label()),
            None => (0..self.shards.len()).collect(),
        };
        let (degraded, live_targets, bodies) = if targets.is_empty() {
            (Vec::new(), Vec::new(), Vec::new())
        } else {
            let (replies, degraded) = self.fan_partial(&targets, req, parent, allow_partial)?;
            let mut live_targets = Vec::with_capacity(replies.len());
            let mut bodies = Vec::with_capacity(replies.len());
            for (&si, resp) in targets.iter().zip(replies) {
                let Some(resp) = resp else { continue };
                match resp {
                    Response::KnnBatch(b) => {
                        live_targets.push(si);
                        bodies.push(b);
                    }
                    other => {
                        return Err(ClientError::Wire(format!(
                            "expected knn_batch reply, got {}",
                            other.type_name()
                        )))
                    }
                }
            }
            (degraded, live_targets, bodies)
        };
        for (ti, body) in bodies.iter().enumerate() {
            if body.results.len() != nqueries {
                return Err(ClientError::Wire(format!(
                    "shard {} answered {} results for {nqueries} queries",
                    self.shards[live_targets[ti]].addr(),
                    body.results.len(),
                )));
            }
        }
        let mut results = Vec::with_capacity(nqueries);
        let mut merged = SearchStats::default();
        for qi in 0..nqueries {
            let per_shard: Vec<&KnnBody> = bodies.iter().map(|b| &b.results[qi]).collect();
            let row = self.merge_knn(&live_targets, per_shard, k);
            merged.merge(&row.stats);
            results.push(row);
        }
        Ok(KnnBatchBody {
            results,
            stats: merged,
            degraded,
        })
    }

    /// [`ShardRouter::route_knn_batch`] over owned query slices (builds
    /// the request once; examples/tests entry point).
    pub fn knn_batch(
        &mut self,
        queries: &[Vec<f64>],
        k: usize,
        config: Option<&JobConfig>,
    ) -> Result<KnnBatchBody, ClientError> {
        let req = Request::KnnBatch {
            queries: queries.to_vec(),
            k,
            config: config.copied(),
            allow_partial: false,
        };
        self.budget = Budget::none();
        self.route_knn_batch(&req, &Span::none())
    }

    /// Routed single-query k-NN (a batch of one; the series is copied
    /// exactly once, into the request).
    pub fn knn(
        &mut self,
        series: &[f64],
        k: usize,
        config: Option<&JobConfig>,
    ) -> Result<KnnBody, ClientError> {
        let req = Request::KnnBatch {
            queries: vec![series.to_vec()],
            k,
            config: config.copied(),
            allow_partial: false,
        };
        self.budget = Budget::none();
        let mut batch = self.route_knn_batch(&req, &Span::none())?;
        Ok(batch.results.remove(0))
    }

    /// Routed single-query k-NN with fan-out tracing: same single-element
    /// batch as [`ShardRouter::knn`], but per-shard spans nest under
    /// `parent`. The single body inherits the batch-level degraded
    /// annotation (which shard slots the merge survived without).
    fn knn_traced(
        &mut self,
        series: &[f64],
        k: usize,
        config: Option<&JobConfig>,
        allow_partial: bool,
        parent: &Span,
    ) -> Result<KnnBody, ClientError> {
        let req = Request::KnnBatch {
            queries: vec![series.to_vec()],
            k,
            config: config.copied(),
            allow_partial,
        };
        let mut batch = self.route_knn_batch(&req, parent)?;
        let mut one = batch.results.remove(0);
        one.degraded = batch.degraded;
        Ok(one)
    }

    /// Routed matching phase from an already-decoded [`Request::Match`]:
    /// fan the raw capture to the shards owning the configuration set and
    /// merge their per-app rows in shard order — the same row order a
    /// single node produces over the union database. Per-shard round
    /// trips become child spans of `parent`.
    pub fn route_match(&mut self, req: &Request, parent: &Span) -> Result<MatchBody, ClientError> {
        let config = match req {
            Request::Match { config, .. } => config,
            _ => {
                return Err(ClientError::Wire(
                    "route_match needs a Match request".to_string(),
                ))
            }
        };
        let targets = self.owners(&config.label());
        if targets.is_empty() {
            return Ok(MatchBody {
                results: Vec::new(),
                matched: None,
                best_similarity: 0.0,
            });
        }
        let mut results = Vec::new();
        for resp in self.fan(&targets, req, parent)? {
            match resp {
                Response::Match(b) => results.extend(b.results),
                other => {
                    return Err(ClientError::Wire(format!(
                        "expected match reply, got {}",
                        other.type_name()
                    )))
                }
            }
        }
        // Recompute the winner over the merged rows with the single-node
        // rule: first row wins ties, strict improvement replaces.
        let mut best: Option<(usize, f64)> = None;
        for (i, r) in results.iter().enumerate() {
            if best.map_or(true, |(_, bs)| r.similarity > bs) {
                best = Some((i, r.similarity));
            }
        }
        let (matched, best_similarity) = match best {
            Some((i, s)) if s >= MATCH_THRESHOLD => (Some(results[i].app.clone()), s),
            Some((_, s)) => (None, s),
            None => (None, 0.0),
        };
        Ok(MatchBody {
            results,
            matched,
            best_similarity,
        })
    }

    /// [`ShardRouter::route_match`] over an owned capture (builds the
    /// request once; examples/tests entry point).
    pub fn match_config(
        &mut self,
        series: &[f64],
        config: &JobConfig,
    ) -> Result<MatchBody, ClientError> {
        let req = Request::Match {
            series: series.to_vec(),
            config: *config,
        };
        self.budget = Budget::none();
        self.route_match(&req, &Span::none())
    }
}

/// Dispatch one routed request. Stream commands are rejected: sessions
/// live on the shard owning their configuration set.
pub fn dispatch_routed(
    req: &Request,
    router: &Mutex<ShardRouter>,
) -> Result<Response, ServerError> {
    dispatch_routed_deadline(req, router, &Span::none(), None)
}

/// [`dispatch_routed`] with fan-out tracing: per-command spans (and the
/// per-shard round-trip spans under them) nest under `parent`.
pub fn dispatch_routed_traced(
    req: &Request,
    router: &Mutex<ShardRouter>,
    parent: &Span,
) -> Result<Response, ServerError> {
    dispatch_routed_deadline(req, router, parent, None)
}

/// [`dispatch_routed_traced`] under an optional request deadline (the v2
/// envelope's `deadline_ms`): fan-out socket waits are budgeted against
/// it and an exhausted budget answers with the typed `deadline_exceeded`
/// error. `None` is exactly the undeadlined behavior.
pub fn dispatch_routed_deadline(
    req: &Request,
    router: &Mutex<ShardRouter>,
    parent: &Span,
    deadline_ms: Option<u64>,
) -> Result<Response, ServerError> {
    let to_server = |e: ClientError| match e {
        ClientError::Server(se) => se,
        other => ServerError::new(ErrorCode::ShardUnavailable, other.to_string()),
    };
    // A panic while the lock was held (a bug elsewhere) poisons it; report
    // that as a typed Internal error rather than cascading the panic into
    // every later connection.
    let mut r = match router.lock() {
        Ok(guard) => guard,
        Err(_) => return Err(ServerError::new(ErrorCode::Internal, "router lock poisoned")),
    };
    // Start the budget clock after the lock: time queued behind another
    // request's fan-out must not eat this request's deadline (routed
    // dispatch serializes; queueing is scheduling, not fan-out).
    let budget = Budget::start(&r.tracer, deadline_ms);
    r.budget = budget;
    match req {
        Request::Ping => Ok(Response::Pong),
        Request::Apps => Ok(Response::Apps(r.apps())),
        Request::ShardInfo => Ok(Response::ShardInfo(r.aggregate_info())),
        Request::Stats => Ok(Response::Stats(StatsBody {
            report: r.metrics().report(),
            db_entries: r.total_entries(),
            live_sessions: 0,
        })),
        Request::Metrics => Ok(Response::Metrics(r.metrics().snapshot())),
        Request::Knn {
            series,
            k,
            config,
            allow_partial,
        } => {
            let span = parent.child("knn");
            span.event("k", *k as u64);
            r.knn_traced(series, *k, config.as_ref(), *allow_partial, &span)
                .map(Response::Knn)
                .map_err(to_server)
        }
        // Fan the decoded request itself — no payload re-clone on the
        // router's hot path.
        Request::KnnBatch { queries, .. } => {
            let span = parent.child("knn_batch");
            span.event("queries", queries.len() as u64);
            r.route_knn_batch(req, &span)
                .map(Response::KnnBatch)
                .map_err(to_server)
        }
        Request::Match { .. } => {
            let span = parent.child("match");
            r.route_match(req, &span)
                .map(Response::Match)
                .map_err(to_server)
        }
        Request::StreamOpen { .. }
        | Request::StreamFeed { .. }
        | Request::StreamPoll { .. }
        | Request::StreamPollAll { .. }
        | Request::StreamClose { .. }
        | Request::StreamTune { .. } => Err(ServerError::bad_request(
            "stream sessions are not routed; open them against the shard owning the config set",
        )),
        // Each flight recorder is process-local forensics; a merged dump
        // would scramble span ids across processes. Ask each shard.
        Request::TraceDump => Err(ServerError::bad_request(
            "trace_dump is not routed; ask each shard directly",
        )),
    }
}

/// Decode, route and render one request line against the router —
/// the router-side sibling of `server::handle_line` (same envelopes, same
/// error accounting, same `decode` / `handle` / `encode` span taxonomy).
pub fn route_line(
    line: &str,
    router: &Mutex<ShardRouter>,
    metrics: &Metrics,
    tracer: &TraceHandle,
) -> Json {
    let t0 = tracer.timestamp();
    let (wire, decoded) = decode_line(line);
    let t1 = tracer.timestamp();
    let (remote, key, deadline_ms) = match wire {
        Wire::V2 {
            trace,
            id,
            deadline_ms,
        } => (trace, id, deadline_ms),
        Wire::V1 => (0, 0, None),
    };
    // Same sampling protocol as `server::handle_line`: the decision made
    // here rides every fan-out envelope (see `ShardRouter::fan`), so the
    // router and its shards keep or drop the same requests.
    let root = tracer.root_sampled("request", remote, key);
    if tracer.enabled() {
        if root.active() {
            metrics.inc_spans_recorded();
            tracer.span_at("decode", root.id(), t0, t1);
        } else {
            metrics.inc_spans_sampled_out();
        }
    }
    let result = {
        let handle = root.child("handle");
        decoded.and_then(|req| {
            handle.note("type", req.type_name());
            dispatch_routed_deadline(&req, router, &handle, deadline_ms)
        })
    };
    if let Err(e) = &result {
        metrics.inc_errors();
        metrics.inc_proto_error(e.code);
        root.note("error", e.code.as_str());
    }
    let encode = root.child("encode");
    let reply = encode_reply(&wire, &result);
    drop(encode);
    reply
}

/// The routing front-end: a TCP server speaking the same line protocol as
/// the shards (both envelopes), forwarding searches through a
/// [`ShardRouter`].
pub struct RouterServer {
    listener: TcpListener,
    router: Arc<Mutex<ShardRouter>>,
    metrics: Arc<Metrics>,
    /// The router's trace handle, cloned out before the router moves into
    /// its lock so connection loops can time and span without locking.
    tracer: TraceHandle,
    stop: Arc<AtomicBool>,
}

impl RouterServer {
    /// Bind to `addr` (port 0 for ephemeral). The router's own metrics
    /// registry doubles as the server's, and its tracer (if any —
    /// [`ShardRouter::with_tracer`]) spans every front-end request.
    pub fn bind(addr: &str, router: ShardRouter) -> Result<RouterServer> {
        let metrics = Arc::clone(router.metrics());
        let tracer = router.tracer.clone();
        let listener = TcpListener::bind(addr)?;
        Ok(RouterServer {
            listener,
            router: Arc::new(Mutex::new(router)),
            metrics,
            tracer,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Stop handle: set true and connect once to unblock accept().
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Serve until the stop flag is raised (default read timeout).
    pub fn serve(&self, workers: usize) -> Result<()> {
        self.serve_with(workers, READ_TIMEOUT)
    }

    /// Serve until the stop flag is raised. Connections are accepted on a
    /// pool; routed dispatch serializes on the router lock (each routed
    /// search already fans across every shard, so cross-request
    /// parallelism would only thrash the shards).
    pub fn serve_with(&self, workers: usize, read_timeout: Duration) -> Result<()> {
        let pool = ThreadPool::new(workers.max(1));
        log::info!("routing on {}", self.listener.local_addr()?);
        for conn in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let router = Arc::clone(&self.router);
                    let metrics = Arc::clone(&self.metrics);
                    let tracer = self.tracer.clone();
                    let stop = Arc::clone(&self.stop);
                    pool.execute(move || {
                        if let Err(e) = route_connection(
                            stream,
                            &router,
                            &metrics,
                            &tracer,
                            &stop,
                            read_timeout,
                        ) {
                            log::debug!("router connection ended: {e:#}");
                        }
                    });
                }
                Err(e) => log::warn!("router accept failed: {e}"),
            }
        }
        Ok(())
    }
}

fn route_connection(
    stream: TcpStream,
    router: &Mutex<ShardRouter>,
    metrics: &Metrics,
    tracer: &TraceHandle,
    stop: &AtomicBool,
    read_timeout: Duration,
) -> Result<()> {
    // Same hardened read loop as the match server (bounded line framing,
    // idle ticks, structured rejects); the router has no sessions to reap.
    serve_connection_lines(
        stream,
        metrics,
        tracer,
        stop,
        read_timeout,
        || (),
        |line| route_line(line, router, metrics, tracer),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routed_stream_commands_are_rejected() {
        // A router with zero shards still dispatches local commands.
        let router = Mutex::new(ShardRouter {
            shards: Vec::new(),
            metrics: Arc::new(Metrics::new()),
            tracer: TraceHandle::disabled(),
            budget: Budget::none(),
        });
        let err = dispatch_routed(&Request::StreamPollAll { k: 3 }, &router).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("not routed"), "{}", err.message);
        // Local aggregates answer without any shard traffic.
        match dispatch_routed(&Request::ShardInfo, &router).unwrap() {
            Response::ShardInfo(info) => {
                assert_eq!(info.entries, 0);
                assert!(info.apps.is_empty());
            }
            other => panic!("{other:?}"),
        }
        match dispatch_routed(&Request::Ping, &router).unwrap() {
            Response::Pong => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn merge_is_deterministic_on_ties() {
        use crate::protocol::NeighborRow;
        let shard = |addr: &str, base: usize| Shard {
            base,
            entries: 2,
            apps: vec![],
            configs: vec![],
            replicas: vec![Replica {
                addr: addr.into(),
                client: Some(unconnected_client()),
                breaker: Breaker::new(),
            }],
            active: 0,
        };
        let router = ShardRouter {
            shards: vec![shard("a", 0), shard("b", 2)],
            metrics: Arc::new(Metrics::new()),
            tracer: TraceHandle::disabled(),
            budget: Budget::none(),
        };
        let row = |index: usize, distance: f64| NeighborRow {
            index,
            app: "wordcount".into(),
            config: "c".into(),
            distance,
            similarity: 0.0,
        };
        // Shard b holds an equal-distance row; global tie must resolve to
        // the lower global index (shard a's entry 1 = global 1, before
        // shard b's entry 0 = global 2).
        let a = KnnBody {
            neighbors: vec![row(0, 0.5), row(1, 1.0)],
            stats: SearchStats::default(),
            degraded: vec![],
        };
        let b = KnnBody {
            neighbors: vec![row(0, 1.0), row(1, 2.0)],
            stats: SearchStats::default(),
            degraded: vec![],
        };
        let merged = router.merge_knn(&[0, 1], vec![&a, &b], 3);
        let got: Vec<(usize, f64)> = merged.neighbors.iter().map(|r| (r.index, r.distance)).collect();
        assert_eq!(got, vec![(0, 0.5), (1, 1.0), (2, 1.0)]);
    }

    #[test]
    fn breaker_trips_after_consecutive_failures_and_probes_half_open() {
        let mut b = Breaker::new();
        assert_eq!(b.state(), BreakerState::Closed);
        // One short of the threshold keeps it closed; success resets.
        for _ in 0..BREAKER_THRESHOLD - 1 {
            assert!(!b.record_failure());
        }
        b.record_success();
        assert_eq!(b.state(), BreakerState::Closed);
        // The full run of consecutive failures trips it exactly once.
        let mut trips = 0;
        for _ in 0..BREAKER_THRESHOLD {
            if b.record_failure() {
                trips += 1;
            }
        }
        assert_eq!(trips, 1);
        assert_eq!(b.state(), BreakerState::Open);
        // Open skips exactly BREAKER_COOLDOWN - 1 admissions, then admits
        // a single half-open probe.
        for _ in 0..BREAKER_COOLDOWN - 1 {
            assert_eq!(b.try_admit(), (false, false));
        }
        assert_eq!(b.try_admit(), (true, true));
        assert_eq!(b.state(), BreakerState::HalfOpen);
        // A failed probe re-trips immediately (one failure, not three).
        assert!(b.record_failure());
        assert_eq!(b.state(), BreakerState::Open);
        // A successful probe closes it for good.
        for _ in 0..BREAKER_COOLDOWN {
            b.try_admit();
        }
        assert_eq!(b.state(), BreakerState::HalfOpen);
        b.record_success();
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.try_admit(), (true, false));
    }

    #[test]
    fn budget_expires_and_subdivides_attempt_timeouts() {
        use crate::trace::{InMemoryTracker, VirtualClock};
        let tracer = TraceHandle::new(
            std::sync::Arc::new(InMemoryTracker::new()),
            std::sync::Arc::new(VirtualClock::new(1_000_000)), // 1ms per read
        );
        // now_ns reads tick the virtual clock 1ms at a time.
        let b = Budget::start(&tracer, Some(10));
        let rem = b.remaining(&tracer).unwrap();
        assert!(rem <= Duration::from_millis(10));
        assert!(!b.expired(&tracer));
        // Nine more reads put us past the 10ms deadline.
        for _ in 0..9 {
            tracer.now_ns();
        }
        assert!(b.expired(&tracer));
        // Unbounded budget never expires.
        let none = Budget::none();
        assert_eq!(none.remaining(&tracer), None);
        assert!(!none.expired(&tracer));
    }

    /// A client that never connected (test-only: merge logic needs a
    /// `Shard` but never touches the socket).
    fn unconnected_client() -> MrtunerClient {
        // Port 1 on localhost is essentially never listening; but to keep
        // the test hermetic we do not even try: construct via connect to a
        // listener we immediately satisfy.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let _ = listener.accept();
        });
        let client = MrtunerClient::connect(&addr.to_string()).unwrap();
        t.join().unwrap();
        client
    }
}
