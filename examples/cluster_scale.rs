//! Cluster-scale matching with wavelet signatures — the paper's §5
//! future-work plan (E6).
//!
//! On an N-node cluster each application yields 3N series (CPU, disk,
//! memory per node). Full DTW over 3N pairs is quadratic and expensive; the
//! paper proposes comparing fixed-length *wavelet coefficient* vectors with
//! a plain distance instead. This example implements both and reports:
//!   * whether the wavelet route reproduces the DTW route's decision,
//!   * the speedup from replacing DTW with signature distances.
//!
//! Run with: `cargo run --release --example cluster_scale [nodes]`

use mrtuner::coordinator::SystemConfig;
use mrtuner::dtw::{band_radius, banded::dtw_banded, corr::similarity_from_alignment};
use mrtuner::signal::wavelet::{signature, signature_distance, Family};
use mrtuner::simulator::cluster::ClusterConfig;
use mrtuner::simulator::engine::simulate;
use mrtuner::simulator::job::JobConfig;
use mrtuner::util::rng::Rng;
use mrtuner::workloads::{workload_for, AppId};
use std::time::Instant;

/// 3N resource series for one app run.
fn capture(app: AppId, nodes: usize, cfg: &JobConfig, seed: u64) -> Vec<Vec<f64>> {
    let w = workload_for(app);
    let cluster = ClusterConfig::cluster(nodes);
    let sc = SystemConfig::default();
    let r = simulate(w.as_ref(), cfg, &cluster, &sc.noise, &mut Rng::new(seed));
    let mut series = Vec::with_capacity(3 * nodes);
    for node in &r.per_node {
        for s in [&node.cpu, &node.disk, &node.mem] {
            series.push(mrtuner::signal::preprocess(s));
        }
    }
    series
}

/// Mean pairwise similarity over corresponding series, full DTW route.
fn dtw_similarity(a: &[Vec<f64>], b: &[Vec<f64>]) -> f64 {
    let sims: Vec<f64> = a
        .iter()
        .zip(b.iter())
        .map(|(x, y)| {
            let r = dtw_banded(x, y, band_radius(x.len(), y.len()));
            similarity_from_alignment(&r, x, y)
        })
        .collect();
    mrtuner::util::stats::mean(&sims)
}

/// Mean signature distance (lower = more similar), wavelet route (M=32).
fn wavelet_distance(a: &[Vec<f64>], b: &[Vec<f64>]) -> f64 {
    let ds: Vec<f64> = a
        .iter()
        .zip(b.iter())
        .map(|(x, y)| {
            let sx = signature(x, Family::Db4, 32);
            let sy = signature(y, Family::Db4, 32);
            signature_distance(&sx, &sy)
        })
        .collect();
    mrtuner::util::stats::mean(&ds)
}

fn main() {
    mrtuner::util::logging::init();
    let nodes: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(8);
    let cfg = JobConfig::new(4 * nodes, 2 * nodes, 16.0, 80.0 * nodes as f64);
    println!("cluster: {nodes} nodes, job {}, 3N = {} series/app", cfg.label(), 3 * nodes);

    let exim = capture(AppId::EximParse, nodes, &cfg, 1);
    let wc = capture(AppId::WordCount, nodes, &cfg, 2);
    let ts = capture(AppId::TeraSort, nodes, &cfg, 3);

    let t0 = Instant::now();
    let s_wc = dtw_similarity(&exim, &wc);
    let s_ts = dtw_similarity(&exim, &ts);
    let dtw_time = t0.elapsed();
    println!("\nDTW route     : exim~wordcount {s_wc:.1}%  exim~terasort {s_ts:.1}%  ({:.1} ms)", dtw_time.as_secs_f64() * 1e3);

    let t1 = Instant::now();
    let d_wc = wavelet_distance(&exim, &wc);
    let d_ts = wavelet_distance(&exim, &ts);
    let wav_time = t1.elapsed();
    println!("wavelet route : exim~wordcount d={d_wc:.3}  exim~terasort d={d_ts:.3}  ({:.1} ms)", wav_time.as_secs_f64() * 1e3);

    let speedup = dtw_time.as_secs_f64() / wav_time.as_secs_f64().max(1e-9);
    println!("\nwavelet signatures are {speedup:.0}x faster on {} series pairs", 3 * nodes);
    let dtw_says_wc = s_wc > s_ts;
    let wavelet_says_wc = d_wc < d_ts;
    println!("decision agreement: dtw->wordcount={dtw_says_wc} wavelet->wordcount={wavelet_says_wc}");
    assert!(dtw_says_wc, "DTW route must pick WordCount");
    assert!(wavelet_says_wc, "wavelet route must agree with DTW");
    assert!(speedup > 5.0, "wavelet route should be much faster");
}
