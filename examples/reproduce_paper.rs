//! Reproduce the paper's evaluation artifacts end to end:
//!   * Table 1  — similarity % of Exim vs {WordCount, TeraSort} under the
//!     four printed configuration sets (8 reference rows x 4 query columns);
//!   * Figure 5 — the same data as per-config bar series (CSV);
//!   * Figure 6 — sample aligned time-series pairs (CSV).
//!
//! Run with: `cargo run --release --example reproduce_paper`
//! CSVs land in `target/experiments/`.

use mrtuner::coordinator::{matcher::Matcher, print_table1, ConfigGrid, SystemConfig, TuningSystem};
use mrtuner::dtw::{band_radius, banded::dtw_banded};
use mrtuner::prelude::*;
use std::io::Write;

fn main() {
    mrtuner::util::logging::init();
    let out_dir = std::path::Path::new("target/experiments");
    std::fs::create_dir_all(out_dir).unwrap();

    let grid = ConfigGrid::paper_table1();
    let mut sys = TuningSystem::new(SystemConfig::default());
    sys.profile_app(AppId::WordCount, &grid);
    sys.profile_app(AppId::TeraSort, &grid);

    let m = Matcher::new(&sys.config, sys.runtime());
    let table = m.similarity_table(AppId::EximParse, &grid, &sys.db);

    // ---- Table 1 ----
    println!("== Table 1: similarity of Exim mainlog parsing vs reference apps ==");
    print_table1(&table, &grid);

    // ---- Figure 5: CSV of the same series ----
    let mut f5 = std::fs::File::create(out_dir.join("figure5.csv")).unwrap();
    writeln!(f5, "query_config,reference_app,reference_config,similarity_pct").unwrap();
    for c in &table {
        writeln!(
            f5,
            "{},{},{},{:.4}",
            c.config.label(),
            c.reference_app.name(),
            c.reference_config.label(),
            c.similarity
        )
        .unwrap();
    }
    println!("figure5.csv written ({} cells)", table.len());

    // ---- Figure 6: aligned sample series ----
    let cfg = grid.configs[0];
    let profiler = mrtuner::coordinator::profiler::Profiler::new(&sys.config, sys.runtime());
    let exim = profiler.profile_one(AppId::EximParse, &cfg);
    let mut f6 = std::fs::File::create(out_dir.join("figure6.csv")).unwrap();
    writeln!(f6, "pair,t,exim,reference_warped").unwrap();
    for app in [AppId::WordCount, AppId::TeraSort] {
        let e = sys
            .db
            .entries()
            .iter()
            .find(|e| e.app == app && e.config_key() == cfg.label())
            .expect("profiled");
        let r = dtw_banded(
            &exim.series,
            &e.series,
            band_radius(exim.series.len(), e.series.len()),
        );
        let warped = r.warp_onto_x(&e.series, exim.series.len());
        for (t, (x, y)) in exim.series.iter().zip(&warped).enumerate() {
            writeln!(f6, "exim-vs-{},{t},{x:.5},{y:.5}", app.name()).unwrap();
        }
        let sim = mrtuner::dtw::corr::similarity_from_alignment(&r, &exim.series, &e.series);
        println!("figure6: exim vs {:10} at {}: {:.1}%", app.name(), cfg.label(), sim);
    }
    println!("figure6.csv written");

    // ---- validation (the paper's qualitative claims) ----
    let diag_wc: Vec<f64> = table
        .iter()
        .filter(|c| {
            c.reference_app == AppId::WordCount && c.reference_config.label() == c.config.label()
        })
        .map(|c| c.similarity)
        .collect();
    let same_cfg_ts: Vec<f64> = table
        .iter()
        .filter(|c| {
            c.reference_app == AppId::TeraSort && c.reference_config.label() == c.config.label()
        })
        .map(|c| c.similarity)
        .collect();
    let min_diag = diag_wc.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("\nvalidation:");
    println!("  min same-config Exim~WordCount similarity: {min_diag:.1}% (paper: 91.8%)");
    let wins = diag_wc
        .iter()
        .zip(&same_cfg_ts)
        .filter(|(wc, ts)| wc > ts)
        .count();
    println!("  Exim~WordCount beats Exim~TeraSort on {wins}/4 same-config cells (paper: 4/4)");
    assert!(min_diag >= 90.0, "diagonal below the paper's 90% acceptance");
    assert_eq!(wins, 4, "WordCount must dominate TeraSort on the diagonal");
}
