//! The [`Workload`] trait and the per-application cost model.

use super::AppId;
use crate::util::rng::Rng;

/// Emit sink for map output pairs.
pub type Emit<'a> = dyn FnMut(&[u8], &[u8]) + 'a;

/// Per-application resource cost model used by the discrete-event simulator
/// to scale the *really executed* small-sample behaviour to full job sizes.
///
/// CPU costs are in seconds of a single reference core (the paper's 2.26 GHz
/// Centrino) per MB processed; selectivities are output/input byte ratios.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// CPU seconds per input MB in the map function (parse/tokenize).
    pub map_cpu_s_per_mb: f64,
    /// Intermediate bytes emitted per input byte (post-combiner).
    pub map_selectivity: f64,
    /// CPU seconds per intermediate MB for spill sort + combine.
    pub sort_cpu_s_per_mb: f64,
    /// CPU seconds per shuffled MB in the reduce function.
    pub reduce_cpu_s_per_mb: f64,
    /// Output bytes per shuffled byte.
    pub reduce_selectivity: f64,
    /// Task JVM startup cost in CPU seconds (Hadoop 0.20 forks per task).
    pub startup_cpu_s: f64,
}

impl CostModel {
    /// Sanity guard used by property tests.
    pub fn is_plausible(&self) -> bool {
        self.map_cpu_s_per_mb > 0.0
            && self.map_selectivity > 0.0
            && self.sort_cpu_s_per_mb >= 0.0
            && self.reduce_cpu_s_per_mb >= 0.0
            && self.reduce_selectivity > 0.0
            && self.startup_cpu_s >= 0.0
    }
}

/// A MapReduce application: synthetic input generation plus the *actual*
/// map/combine/reduce functions, plus the calibrated cost model.
pub trait Workload: Send + Sync {
    /// Which application this is.
    fn id(&self) -> AppId;

    /// Generate approximately `bytes` of realistic input (record-aligned;
    /// the result may overshoot by up to one record).
    fn generate(&self, bytes: usize, rng: &mut Rng) -> Vec<u8>;

    /// Split input into at most `n` record-aligned chunks (HDFS splits).
    /// Default: newline-aligned; fixed-width workloads override.
    fn split<'a>(&self, input: &'a [u8], n: usize) -> Vec<&'a [u8]> {
        line_splits(input, n)
    }

    /// Route a key to one of `r` reducers. Default: FNV-1a hash
    /// (Hadoop's HashPartitioner); TeraSort overrides with its range
    /// partitioner built from sampled keys.
    fn partition(&self, key: &[u8], r: usize) -> usize {
        (super::mapreduce::fnv1a(key) % r as u64) as usize
    }

    /// Run the map function over one input split, emitting key/value pairs.
    fn map(&self, split: &[u8], emit: &mut Emit);

    /// Combine values for one key map-side (Hadoop combiner). The default
    /// is the identity (no combiner).
    fn combine(&self, _key: &[u8], values: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
        values
    }

    /// Run the reduce function for one key group, appending output bytes.
    fn reduce(&self, key: &[u8], values: &[Vec<u8>], out: &mut Vec<u8>);

    /// Calibrated default cost model (see `calibrate` for re-measurement).
    fn default_costs(&self) -> CostModel;

    /// Relative shuffle-partition weights for `r` reducers (sum = 1).
    /// Default: uniform (hash partitioning of well-spread keys).
    fn partition_weights(&self, r: usize, _rng: &mut Rng) -> Vec<f64> {
        vec![1.0 / r as f64; r]
    }

    /// Re-measure the CPU cost terms by really executing the map/reduce
    /// functions on `sample_bytes` of generated data and timing them on the
    /// host, then rescaling to the reference core via `host_speed_factor`
    /// (host-seconds × factor = reference-seconds). Selectivities are
    /// measured exactly (byte counts, not timing).
    fn calibrate(&self, sample_bytes: usize, host_speed_factor: f64, seed: u64) -> CostModel {
        let mut rng = Rng::new(seed);
        let input = self.generate(sample_bytes, &mut rng);
        let mb = input.len() as f64 / (1024.0 * 1024.0);

        // Calibration measures *real* host CPU time by design — a virtual
        // clock would defeat its purpose. lint: allow(no-raw-clock)
        let t0 = std::time::Instant::now();
        let mut inter_bytes = 0usize;
        let mut pairs: Vec<(Vec<u8>, Vec<u8>)> = Vec::new();
        self.map(&input, &mut |k, v| {
            inter_bytes += k.len() + v.len();
            pairs.push((k.to_vec(), v.to_vec()));
        });
        let map_s = t0.elapsed().as_secs_f64();

        // Group (sort) and combine — the spill-side cost.
        // lint: allow(no-raw-clock) real host timing, as above.
        let t1 = std::time::Instant::now();
        pairs.sort();
        let mut combined_bytes = 0usize;
        let mut groups: Vec<(Vec<u8>, Vec<Vec<u8>>)> = Vec::new();
        for (k, v) in pairs {
            match groups.last_mut() {
                Some((lk, vs)) if *lk == k => vs.push(v),
                _ => groups.push((k, vec![v])),
            }
        }
        for (k, vs) in &mut groups {
            let taken = std::mem::take(vs);
            *vs = self.combine(k, taken);
            combined_bytes += k.len() + vs.iter().map(|v| v.len()).sum::<usize>();
        }
        let sort_s = t1.elapsed().as_secs_f64();

        // Reduce.
        // lint: allow(no-raw-clock) real host timing, as above.
        let t2 = std::time::Instant::now();
        let mut out = Vec::new();
        for (k, vs) in &groups {
            self.reduce(k, vs, &mut out);
        }
        let reduce_s = t2.elapsed().as_secs_f64();

        let inter_mb = (combined_bytes.max(1)) as f64 / (1024.0 * 1024.0);
        let defaults = self.default_costs();
        CostModel {
            map_cpu_s_per_mb: (map_s * host_speed_factor / mb).max(1e-4),
            map_selectivity: combined_bytes.max(1) as f64 / input.len().max(1) as f64,
            sort_cpu_s_per_mb: (sort_s * host_speed_factor / inter_mb).max(1e-5),
            reduce_cpu_s_per_mb: (reduce_s * host_speed_factor / inter_mb).max(1e-5),
            reduce_selectivity: out.len().max(1) as f64 / combined_bytes.max(1) as f64,
            startup_cpu_s: defaults.startup_cpu_s,
        }
        .clamp_to_plausible()
    }
}

impl CostModel {
    fn clamp_to_plausible(mut self) -> CostModel {
        self.map_selectivity = self.map_selectivity.clamp(1e-4, 2.0);
        self.reduce_selectivity = self.reduce_selectivity.clamp(1e-4, 2.0);
        self
    }
}

/// Split a byte buffer on newline boundaries into at most `n` chunks of
/// roughly equal size — HDFS-style record-aligned input splits.
pub fn line_splits(input: &[u8], n: usize) -> Vec<&[u8]> {
    if input.is_empty() || n == 0 {
        return Vec::new();
    }
    let n = n.min(input.len());
    let target = input.len() / n;
    let mut splits = Vec::with_capacity(n);
    let mut start = 0usize;
    for _ in 0..n - 1 {
        if start >= input.len() {
            break;
        }
        let mut end = (start + target).min(input.len());
        // Advance to the next newline so records stay whole.
        while end < input.len() && input[end] != b'\n' {
            end += 1;
        }
        if end < input.len() {
            end += 1; // include the newline
        }
        if end > start {
            splits.push(&input[start..end]);
        }
        start = end;
    }
    if start < input.len() {
        splits.push(&input[start..]);
    }
    splits
}

/// Split fixed-width records (TeraSort's 100-byte rows) into `n` chunks.
pub fn record_splits(input: &[u8], record: usize, n: usize) -> Vec<&[u8]> {
    let records = input.len() / record;
    if records == 0 || n == 0 {
        return Vec::new();
    }
    let n = n.min(records);
    let per = records / n;
    let extra = records % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0usize;
    for i in 0..n {
        let count = per + usize::from(i < extra);
        let end = start + count * record;
        out.push(&input[start..end]);
        start = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_splits_cover_everything() {
        let data = b"alpha beta\ngamma\ndelta epsilon\nzeta\n".to_vec();
        for n in 1..=6 {
            let splits = line_splits(&data, n);
            let total: usize = splits.iter().map(|s| s.len()).sum();
            assert_eq!(total, data.len(), "n={n}");
            for s in &splits[..splits.len() - 1] {
                assert!(s.ends_with(b"\n"), "split not line-aligned");
            }
        }
    }

    #[test]
    fn record_splits_are_exact() {
        let data = vec![7u8; 100 * 13];
        let splits = record_splits(&data, 100, 4);
        assert_eq!(splits.len(), 4);
        let total: usize = splits.iter().map(|s| s.len()).sum();
        assert_eq!(total, 1300);
        for s in &splits {
            assert_eq!(s.len() % 100, 0);
        }
    }

    #[test]
    fn record_splits_more_chunks_than_records() {
        let data = vec![1u8; 100 * 2];
        let splits = record_splits(&data, 100, 8);
        assert_eq!(splits.len(), 2);
    }
}
