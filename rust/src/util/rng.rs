//! Seeded pseudo-random number generation and the distributions used by the
//! workload generators and the measurement-noise model.
//!
//! Core generator is SplitMix64 (Steele, Lea, Flood 2014) — 64-bit state,
//! full-period, passes BigCrush when used as a stream — which is plenty for
//! workload synthesis and is trivially reproducible across platforms. A
//! [`Pcg32`] is provided as an independent family for property-test sweeps so
//! that test inputs are not correlated with workload data.

/// SplitMix64 generator. Deterministic for a given seed on every platform.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Derive an independent child generator (for per-task streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0x9e37_79b9_7f4a_7c15)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` using Lemire's rejection-free multiply.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f64 {
        // Avoid ln(0).
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Log-normal with underlying normal `(mu, sigma)`.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniform element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Zipf(s) sampler over ranks `1..=n` using precomputed CDF — word-frequency
/// model for the WordCount / text-corpus generator.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build a sampler for `n` ranks with exponent `s` (s≈1 for natural text).
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in &mut cdf {
            *v /= total;
        }
        Zipf { cdf }
    }

    /// Sample a rank in `[0, n)` (0 = most frequent).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).expect("cdf is finite"))
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

/// PCG32 (Melissa O'Neill) — independent generator family used by the
/// property-test sweeps so test-case generation never shares a stream with
/// workload synthesis.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Seed with a state/stream pair.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut g = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        g.next_u32();
        g.state = g.state.wrapping_add(seed);
        g.next_u32();
        g
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform in `[0,1)`.
    pub fn f64(&mut self) -> f64 {
        self.next_u32() as f64 / (1u64 << 32) as f64
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: u32) -> u32 {
        ((self.next_u32() as u64 * n as u64) >> 32) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn zipf_rank_ordering() {
        let z = Zipf::new(100, 1.0);
        let mut r = Rng::new(17);
        let mut counts = [0usize; 100];
        for _ in 0..100_000 {
            counts[z.sample(&mut r)] += 1;
        }
        // Rank 0 strictly dominates rank 9 dominates rank 50.
        assert!(counts[0] > counts[9]);
        assert!(counts[9] > counts[50]);
        // Zipf(1): count(0)/count(1) ≈ 2.
        let ratio = counts[0] as f64 / counts[1] as f64;
        assert!((1.6..2.6).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn pcg_reference_values() {
        // First outputs for seed=42, stream=54 from the PCG reference impl.
        let mut g = Pcg32::new(42, 54);
        let first: Vec<u32> = (0..6).map(|_| g.next_u32()).collect();
        assert_eq!(
            first,
            vec![0xa15c02b7, 0x7b47f409, 0xba1d3330, 0x83d2f293, 0xbfa4784b, 0xcbed606e]
        );
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(5);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
