//! Seeded property sweeps across module boundaries (proptest is not
//! vendorable offline; `util::rng::Pcg32` drives the case generation).

use mrtuner::dtw::{
    band_radius,
    banded::{dtw_banded, dtw_banded_distance_cutoff},
    fastdtw::fastdtw,
    full,
};
use mrtuner::index::{lb, Envelope, IndexedDb, DEFAULT_BLOCK};
use mrtuner::signal::{self, chebyshev::Sos, normalize, resample, wavelet};
use mrtuner::simulator::cluster::ClusterConfig;
use mrtuner::simulator::engine::simulate;
use mrtuner::simulator::job::JobConfig;
use mrtuner::signal::noise::NoiseModel;
use mrtuner::util::json::Json;
use mrtuner::util::rng::{Pcg32, Rng};
use mrtuner::workloads::{mapreduce::run_job, workload_for, AppId};

fn series(g: &mut Pcg32, len: usize) -> Vec<f64> {
    let mut v = 0.5;
    (0..len)
        .map(|_| {
            v = (v + (g.f64() - 0.5) * 0.2).clamp(0.0, 1.0);
            v
        })
        .collect()
}

#[test]
fn dtw_impl_ordering_invariants() {
    // full <= banded <= fastdtw-with-tiny-radius never violated;
    // full == banded when the band is the whole matrix.
    let mut g = Pcg32::new(100, 1);
    for _ in 0..40 {
        let n = 8 + g.below(120) as usize;
        let m = 8 + g.below(120) as usize;
        let x = series(&mut g, n);
        let y = series(&mut g, m);
        let f = full::dtw_distance(&x, &y);
        let b = dtw_banded(&x, &y, band_radius(n, m)).distance;
        let fd = fastdtw(&x, &y, 6).distance;
        assert!(b >= f - 1e-9, "band below exact: {b} < {f}");
        assert!(fd >= f - 1e-9, "fastdtw below exact: {fd} < {f}");
        let wide = dtw_banded(&x, &y, n.max(m)).distance;
        assert!((wide - f).abs() < 1e-9);
    }
}

#[test]
fn lower_bound_cascade_invariant() {
    // Every pruning stage under-estimates the banded distance it gates
    // (that is what makes the index exact), the PAA bound never exceeds
    // the Keogh bound it summarizes, and the unconstrained DTW never
    // exceeds the banded one. Note LB_Kim and LB_Keogh are *not* mutually
    // ordered: Kim uses exact endpoint costs, Keogh relaxed envelopes.
    let mut g = Pcg32::new(120, 1);
    for _ in 0..40 {
        let n = 4 + g.below(200) as usize;
        let m = 4 + g.below(200) as usize;
        let x = series(&mut g, n);
        let y = series(&mut g, m);
        let r = band_radius(n, m);
        let env = Envelope::build(&y, DEFAULT_BLOCK);
        let qext = lb::query_extrema(&x, DEFAULT_BLOCK);

        let banded = dtw_banded(&x, &y, r).distance;
        let exact = full::dtw_distance(&x, &y);
        let kim = lb::lb_kim(&x, &y);
        let keogh = lb::lb_keogh(&x, &env, r);
        let paa = lb::lb_paa(&qext, n, DEFAULT_BLOCK, &env, r);

        assert!(kim <= exact + 1e-9, "LB_Kim {kim} > full {exact}");
        assert!(kim <= banded + 1e-9, "LB_Kim {kim} > banded {banded}");
        assert!(paa <= keogh + 1e-9, "LB_PAA {paa} > LB_Keogh {keogh}");
        assert!(keogh <= banded + 1e-9, "LB_Keogh {keogh} > banded {banded}");
        assert!(exact <= banded + 1e-9, "full {exact} > banded {banded}");

        // The early-abandoning DP is bit-identical to the banded DP when
        // it completes, and only abandons above the cutoff.
        let ea = dtw_banded_distance_cutoff(&x, &y, r, f64::INFINITY).unwrap();
        assert_eq!(ea.to_bits(), banded.to_bits());
        match dtw_banded_distance_cutoff(&x, &y, r, banded * 0.5) {
            None => assert!(banded > 0.0),
            Some(d) => assert_eq!(d.to_bits(), banded.to_bits()),
        }
    }
}

#[test]
fn indexed_top1_matches_brute_force_across_seeds() {
    // The cascade is a pure accelerator: for any seed, database and query,
    // indexed top-1 (and top-3) equal the brute-force scan — same entry,
    // bit-identical distance.
    use mrtuner::database::profile::ProfileEntry;
    use mrtuner::database::store::ReferenceDb;
    for seed in 1..=6u64 {
        let mut g = Pcg32::new(200 + seed, seed);
        let mut db = ReferenceDb::new();
        let apps = [AppId::WordCount, AppId::TeraSort, AppId::EximParse];
        for i in 0..40usize {
            let len = 30 + g.below(300) as usize;
            db.insert(ProfileEntry {
                app: apps[i % apps.len()],
                config: JobConfig::new(1 + i, 2, 10.0, 20.0),
                series: series(&mut g, len),
                raw_len: len,
                completion_secs: 1.0,
            });
        }
        let idx = IndexedDb::from_db(db);
        for _ in 0..5 {
            let q = series(&mut g, 30 + g.below(300) as usize);
            let (fast, stats) = idx.knn(&q, 3);
            let slow = idx.brute_force(&q, 3);
            assert_eq!(fast.len(), slow.len());
            for (a, b) in fast.iter().zip(&slow) {
                assert_eq!(a.index, b.index, "seed {seed}");
                assert_eq!(
                    a.distance.to_bits(),
                    b.distance.to_bits(),
                    "seed {seed}: {} vs {}",
                    a.distance,
                    b.distance
                );
            }
            assert_eq!(stats.candidates, 40);
            assert_eq!(stats.pruned() + stats.dtw_started(), stats.candidates);
        }
    }
}

#[test]
fn dtw_scale_and_shift_behaviour() {
    // DTW on |a-b| local cost: distance scales linearly with amplitude and
    // is invariant to adding a constant to both series.
    let mut g = Pcg32::new(101, 2);
    for _ in 0..20 {
        let n = 10 + g.below(60) as usize;
        let x = series(&mut g, n);
        let y = series(&mut g, n + 5);
        let d = full::dtw_distance(&x, &y);
        let x2: Vec<f64> = x.iter().map(|v| 3.0 * v).collect();
        let y2: Vec<f64> = y.iter().map(|v| 3.0 * v).collect();
        assert!((full::dtw_distance(&x2, &y2) - 3.0 * d).abs() < 1e-9);
        let x3: Vec<f64> = x.iter().map(|v| v + 7.0).collect();
        let y3: Vec<f64> = y.iter().map(|v| v + 7.0).collect();
        assert!((full::dtw_distance(&x3, &y3) - d).abs() < 1e-9);
    }
}

#[test]
fn warp_preserves_reference_value_set() {
    let mut g = Pcg32::new(102, 3);
    for _ in 0..20 {
        let len = 10 + g.below(50) as usize;
        let x = series(&mut g, len);
        let len = 10 + g.below(50) as usize;
        let y = series(&mut g, len);
        let r = full::dtw(&x, &y);
        let warped = r.warp_onto_x(&y, x.len());
        for v in &warped {
            assert!(y.contains(v), "warped value not from reference");
        }
    }
}

#[test]
fn preprocess_bounds_and_monotone_under_scaling() {
    let mut g = Pcg32::new(103, 4);
    for _ in 0..20 {
        let len = 30 + g.below(300) as usize;
        let raw = series(&mut g, len);
        let p = signal::preprocess(&raw);
        assert_eq!(p.len(), raw.len());
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for &v in &p {
            assert!((0.0..=1.0).contains(&v));
            lo = lo.min(v);
            hi = hi.max(v);
        }
        assert!(lo.abs() < 1e-12 && (hi - 1.0).abs() < 1e-12, "min-max touched");
        // Scaled input gives the identical normalized output (filter is
        // linear; a constant *offset* would excite the IIR transient, so
        // only pure scaling is invariant end-to-end).
        let scaled: Vec<f64> = raw.iter().map(|v| 0.3 * v).collect();
        let p2 = signal::preprocess(&scaled);
        for (a, b) in p.iter().zip(&p2) {
            assert!((a - b).abs() < 1e-9);
        }
    }
}

#[test]
fn filter_never_explodes() {
    // Bounded input -> bounded output for the (stable) default filter.
    let mut g = Pcg32::new(104, 5);
    for _ in 0..10 {
        let sos = Sos::lowpass_default();
        let x: Vec<f64> = (0..2000).map(|_| g.f64() * 2.0 - 1.0).collect();
        let y = sos.filter(&x);
        for v in y {
            assert!(v.abs() < 10.0, "filter output blew up: {v}");
        }
    }
}

#[test]
fn resample_then_resample_back_is_close_for_smooth_series() {
    let mut g = Pcg32::new(105, 6);
    for _ in 0..10 {
        let n = 100 + g.below(200) as usize;
        let sos = Sos::lowpass_default();
        let x = sos.filter(&series(&mut g, n)); // smooth it
        let down = resample::linear(&x, n / 2);
        let back = resample::linear(&down, n);
        let err: f64 = x
            .iter()
            .zip(&back)
            .skip(20)
            .map(|(a, b)| (a - b).abs())
            .sum::<f64>()
            / (n - 20) as f64;
        assert!(err < 0.02, "roundtrip error {err}");
    }
}

#[test]
fn wavelet_signature_distance_is_a_semimetric() {
    let mut g = Pcg32::new(106, 7);
    for _ in 0..15 {
        let len = 64 + g.below(200) as usize;
        let a = series(&mut g, len);
        let len = 64 + g.below(200) as usize;
        let b = series(&mut g, len);
        let sa = wavelet::signature(&a, wavelet::Family::Db4, 16);
        let sb = wavelet::signature(&b, wavelet::Family::Db4, 16);
        assert_eq!(wavelet::signature_distance(&sa, &sa), 0.0);
        let dab = wavelet::signature_distance(&sa, &sb);
        let dba = wavelet::signature_distance(&sb, &sa);
        assert!((dab - dba).abs() < 1e-12);
        assert!(dab >= 0.0);
    }
}

#[test]
fn json_roundtrips_arbitrary_trees() {
    let mut g = Pcg32::new(107, 8);
    fn gen(g: &mut Pcg32, depth: usize) -> Json {
        match if depth == 0 { g.below(4) } else { g.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(g.below(2) == 0),
            2 => Json::Num((g.f64() - 0.5) * 1e6),
            3 => Json::Str(format!("k{}-\"quote\\slash\n", g.below(1000))),
            4 => Json::Arr((0..g.below(5)).map(|_| gen(g, depth - 1)).collect()),
            _ => Json::Obj(
                (0..g.below(5))
                    .map(|i| (format!("key{i}"), gen(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    for _ in 0..200 {
        let v = gen(&mut g, 3);
        let text = v.to_string();
        let back = Json::parse(&text).expect("own output parses");
        // Numbers may differ at the last ulp through the %e formatting; a
        // second round trip must be a fixed point.
        assert_eq!(back.to_string(), Json::parse(&back.to_string()).unwrap().to_string());
        let pretty = Json::parse(&v.to_pretty()).expect("pretty parses");
        assert_eq!(back.to_string(), pretty.to_string());
    }
}

#[test]
fn simulator_conservation_and_monotonicity() {
    let mut g = Pcg32::new(108, 9);
    let cluster = ClusterConfig::pseudo_distributed();
    for _ in 0..12 {
        let app = *[AppId::WordCount, AppId::TeraSort, AppId::EximParse, AppId::Grep]
            .iter()
            .nth(g.below(4) as usize)
            .unwrap();
        let w = workload_for(app);
        let cfg = JobConfig::new(
            1 + g.below(20) as usize,
            1 + g.below(10) as usize,
            (1 + g.below(30)) as f64,
            (10 + g.below(90)) as f64,
        );
        let r = simulate(w.as_ref(), &cfg, &cluster, &NoiseModel::none(), &mut Rng::new(1));
        // Shuffle conservation: total shuffled == input x map selectivity.
        let expected = cfg.input_mb * w.default_costs().map_selectivity;
        assert!(
            (r.counters.shuffle_mb - expected).abs() < 0.05 * expected + 0.5,
            "{app:?} {}: shuffled {} vs expected {expected}",
            cfg.label(),
            r.counters.shuffle_mb
        );
        // Utilization bounded; series spans the run.
        assert_eq!(r.cpu_clean.len(), r.completion_secs.ceil() as usize);
        assert!(r.cpu_clean.iter().all(|&u| (0.0..=1.0).contains(&u)));
        // Task accounting.
        assert_eq!(r.counters.map_tasks, cfg.num_map_tasks());
        assert_eq!(r.counters.reduce_tasks, cfg.reducers.max(1));
    }
}

#[test]
fn simulator_more_work_never_faster() {
    // Completion time is monotone in input size (same config otherwise).
    let cluster = ClusterConfig::pseudo_distributed();
    let w = workload_for(AppId::EximParse);
    let mut last = 0.0;
    for i in [20.0f64, 40.0, 80.0, 160.0] {
        let cfg = JobConfig::new(8, 4, 10.0, i);
        let r = simulate(w.as_ref(), &cfg, &cluster, &NoiseModel::none(), &mut Rng::new(3));
        assert!(
            r.completion_secs > last,
            "I={i}: {} not > {last}",
            r.completion_secs
        );
        last = r.completion_secs;
    }
}

#[test]
fn mapreduce_engine_keys_partition_disjointly() {
    // A key's group is reduced exactly once: keys never appear in more
    // than one reducer's output, and never twice within one reducer.
    // (WordCount and InvertedIndex emit `key \t value` lines.)
    let mut g = Pcg32::new(109, 10);
    for app in [AppId::WordCount, AppId::InvertedIndex] {
        let w = workload_for(app);
        let mut rng = Rng::new(g.next_u32() as u64);
        let input = w.generate(24 * 1024, &mut rng);
        let out = run_job(w.as_ref(), &input, 3, 4);
        let mut owner: std::collections::BTreeMap<Vec<u8>, usize> = Default::default();
        for (ri, ro) in out.reducer_outputs.iter().enumerate() {
            for line in ro.split(|&b| b == b'\n').filter(|l| !l.is_empty()) {
                let key = line.split(|&b| b == b'\t').next().unwrap().to_vec();
                match owner.insert(key.clone(), ri) {
                    None => {}
                    Some(prev) => panic!(
                        "{app:?}: key {:?} reduced twice (reducers {prev} and {ri})",
                        String::from_utf8_lossy(&key)
                    ),
                }
            }
        }
        assert!(owner.len() > 10, "{app:?}: suspiciously few keys");
    }
}

#[test]
fn streaming_prefix_bound_monotone_and_admissible() {
    // Property (a) of the streaming classifier: at every prefix length the
    // lower bound is monotone non-decreasing and never exceeds the final
    // full-series banded DTW distance — under both final-length models,
    // with the online filter + normalization actually driving the state.
    use mrtuner::signal::normalize::OnlineMinMax;
    use mrtuner::streaming::prefix_lb::prefix_lb;
    use mrtuner::streaming::FinalLen;

    let mut g = Pcg32::new(300, 1);
    let sos = Sos::lowpass_default();
    let domain = sos.output_bounds(0.0, 1.0, 1024);
    for round in 0..12 {
        let n = 40 + g.below(220) as usize;
        let m = 40 + g.below(220) as usize;
        let raw = series(&mut g, n);
        let reference = signal::preprocess(&series(&mut g, m));
        let env = Envelope::build(&reference, DEFAULT_BLOCK);
        let final_q = signal::preprocess(&raw);
        let final_dist = dtw_banded(&final_q, &reference, band_radius(n, m)).distance;

        let flen = if round % 2 == 0 {
            FinalLen::Known(n)
        } else {
            FinalLen::AtMost(512)
        };
        let mut st = sos.stream();
        let mut filtered = Vec::new();
        let mut norm = OnlineMinMax::new();
        let mut last = 0.0;
        for &x in &raw {
            let y = st.push(x);
            filtered.push(y);
            norm.push(y);
            let lb = prefix_lb(&filtered, &norm, domain, flen, &env);
            assert!(
                lb >= last - 1e-12,
                "round {round}: bound fell from {last} to {lb} at p={}",
                filtered.len()
            );
            assert!(
                lb <= final_dist + 1e-9,
                "round {round}: bound {lb} > final banded distance {final_dist} at p={}",
                filtered.len()
            );
            last = lb;
        }
    }
}

#[test]
fn completed_stream_session_equals_offline_indexed_top1() {
    // Property (b): a session fed to completion finalizes to exactly the
    // top-1 the offline indexed matcher computes on the full series —
    // same entry, bit-identical distance — for every config bucket.
    use mrtuner::coordinator::batcher::prepare_query;
    use mrtuner::coordinator::profiler::Profiler;
    use mrtuner::coordinator::{ConfigGrid, SystemConfig};
    use mrtuner::database::store::ReferenceDb;
    use mrtuner::index::IndexedDb as Idx;
    use mrtuner::streaming::{DecisionPolicy, FinalLen, StreamSession};

    let sc = SystemConfig {
        workers: 2,
        use_runtime: false,
        ..SystemConfig::default()
    };
    let grid = ConfigGrid::small(9);
    let profiler = Profiler::new(&sc, None);
    let mut db = ReferenceDb::new();
    for app in [AppId::WordCount, AppId::TeraSort] {
        for e in profiler.profile(app, &grid) {
            db.insert(e);
        }
    }
    let idx = Idx::from_db(db);

    for (ci, cfg) in grid.configs.iter().enumerate() {
        let w = workload_for(AppId::EximParse);
        let r = simulate(
            w.as_ref(),
            cfg,
            &sc.cluster,
            &NoiseModel::default(),
            &mut Rng::new(4242 + ci as u64),
        );
        let mut session = StreamSession::open(
            &idx,
            Some(cfg),
            FinalLen::Known(r.cpu_noisy.len()),
            DecisionPolicy::never(),
        );
        let mut source = r.live_stream();
        while let Some(chunk) = source.next_batch(23) {
            session.push(&idx, chunk);
        }
        assert!(session.decision().is_none());
        let (top, _) = session.finalize(&idx, 1);
        let q = prepare_query(&r.cpu_noisy);
        let (want, _) = idx.knn_in_config(&q, &cfg.label(), 1);
        assert_eq!(top.len(), want.len(), "config {}", cfg.label());
        if let (Some(a), Some(b)) = (top.first(), want.first()) {
            assert_eq!(a.index, b.index, "config {}", cfg.label());
            assert_eq!(
                a.distance.to_bits(),
                b.distance.to_bits(),
                "config {}: {} vs {}",
                cfg.label(),
                a.distance,
                b.distance
            );
        }
    }
}

#[test]
fn normalization_idempotent() {
    let mut g = Pcg32::new(110, 11);
    for _ in 0..20 {
        let len = 10 + g.below(100) as usize;
        let x = series(&mut g, len);
        let n1 = normalize::min_max(&x);
        let n2 = normalize::min_max(&n1);
        for (a, b) in n1.iter().zip(&n2) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}

#[test]
fn predictor_intervals_tighten_and_cover_across_seeds() {
    // Seeded sweep of the online final-length predictor (fixed-config
    // variants live in `tuning::predictor`'s unit tests): on noise-free
    // simulator captures with an honest progress signal, every interval
    // covers the true final length, intervals only ever tighten, and any
    // hint issued is consistent with the truth — `Known` lands within
    // the promotion tolerance, `AtMost` never undershoots.
    use mrtuner::simulator::profile_run;
    use mrtuner::streaming::FinalLen;
    use mrtuner::tuning::LengthPredictor;

    let mut g = Pcg32::new(130, 7);
    let apps = AppId::all();
    for case in 0..12u64 {
        let app = apps[g.below(apps.len() as u32) as usize];
        let cfg = JobConfig::new(
            1 + g.below(4) as usize,
            1 + g.below(3) as usize,
            (8 + g.below(24)) as f64,
            (40 + g.below(80)) as f64,
        );
        let res = profile_run(app, &cfg, &NoiseModel::none(), 500 + case);
        let truth = res.cpu_clean.len() as f64;
        // Irregular observation stride: the predictor must not depend on
        // a fixed 1 Hz reporting cadence.
        let mut pred = LengthPredictor::new();
        let mut last: Option<(f64, f64)> = None;
        let mut t = 0.0;
        while t < truth {
            t = (t + 1.0 + g.below(3) as f64).min(truth);
            pred.observe(t / truth, t);
            let Some(p) = pred.predict() else { continue };
            assert!(
                p.lo <= p.hi && p.lo <= p.estimate && p.estimate <= p.hi,
                "{app:?} case {case}: malformed interval [{}, {}] est {}",
                p.lo,
                p.hi,
                p.estimate
            );
            assert!(
                p.lo <= truth + 1e-6 && truth <= p.hi + 1e-6,
                "{app:?} case {case}: [{}, {}] misses truth {truth} at t={t}",
                p.lo,
                p.hi
            );
            if let Some((lo, hi)) = last {
                assert!(
                    p.lo >= lo - 1e-9 && p.hi <= hi + 1e-9,
                    "{app:?} case {case}: interval widened at t={t}",
                );
            }
            last = Some((p.lo, p.hi));
            match pred.final_len_hint(1 << 20) {
                Some(FinalLen::Known(n)) => assert!(
                    (n as f64 - truth).abs() <= truth * 0.1 + 3.0,
                    "{app:?} case {case}: Known({n}) far from truth {truth}"
                ),
                Some(FinalLen::AtMost(n)) => assert!(
                    n as f64 + 1.0 >= truth,
                    "{app:?} case {case}: AtMost({n}) below truth {truth}"
                ),
                None => {}
            }
        }
        assert!(last.is_some(), "{app:?} case {case}: no prediction by run end");
    }
}

#[test]
fn predictor_declines_on_short_prefixes_then_starts_wide() {
    // Graceful degradation: with fewer than four observations or under
    // the minimum progress fraction the predictor declines entirely, and
    // the first hint it does issue — while the confidence band is still
    // wide — is `AtMost`, never a premature `Known`.
    use mrtuner::streaming::FinalLen;
    use mrtuner::tuning::LengthPredictor;

    let mut g = Pcg32::new(131, 9);
    for case in 0..20 {
        let truth = (200 + g.below(1800)) as f64;
        let mut pred = LengthPredictor::new();
        let mut first: Option<FinalLen> = None;
        for i in 1..=(truth as usize / 10) {
            let t = i as f64;
            let frac = t / truth;
            pred.observe(frac, t);
            if pred.observations() < 4 || frac < 0.05 {
                assert!(
                    pred.predict().is_none(),
                    "case {case}: predicted on a short prefix ({} points, p={frac})",
                    pred.observations()
                );
            }
            if first.is_none() {
                first = pred.final_len_hint(1 << 20);
            }
        }
        // Only ~10% of the run was observed, so the band is still wide.
        let first = first.expect("10% of a run is past the minimum progress");
        assert!(
            matches!(first, FinalLen::AtMost(_)),
            "case {case}: premature hint {first:?}"
        );
    }
}

#[test]
fn profile_entries_roundtrip_through_db_json() {
    use mrtuner::database::{profile::ProfileEntry, store::ReferenceDb};
    let mut g = Pcg32::new(111, 12);
    let mut db = ReferenceDb::new();
    for i in 0..30 {
        let app = *[AppId::WordCount, AppId::TeraSort, AppId::EximParse]
            .iter()
            .nth(g.below(3) as usize)
            .unwrap();
        db.insert(ProfileEntry {
            app,
            config: JobConfig::new(1 + i, 1 + (i % 7), 5.0 + i as f64, 10.0 * (i + 1) as f64),
            series: {
                let len = 5 + g.below(60) as usize;
                series(&mut g, len)
            },
            raw_len: 50,
            completion_secs: g.f64() * 1000.0,
        });
    }
    let text = db.to_json().to_string();
    let back = ReferenceDb::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back.len(), db.len());
    for (a, b) in db.entries().iter().zip(back.entries()) {
        assert_eq!(a.app, b.app);
        assert_eq!(a.config_key(), b.config_key());
        assert_eq!(a.series.len(), b.series.len());
        for (x, y) in a.series.iter().zip(&b.series) {
            assert!((x - y).abs() < 1e-9);
        }
    }
}
