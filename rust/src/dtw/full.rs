//! Exact O(N·M) DTW with traceback and warped-series construction.

use super::scratch::{with_thread_scratch, DtwScratch};
use super::{local_cost, CHOICE_DIAG, CHOICE_LEFT, CHOICE_UP};

/// Result of a DTW alignment.
#[derive(Debug, Clone)]
pub struct DtwResult {
    /// Accumulated minimum distance `D(N, M)` (paper eqn. (1)).
    pub distance: f64,
    /// Distance normalized by path length (comparable across lengths).
    pub normalized: f64,
    /// Optimal warping path as `(i, j)` pairs from `(0,0)` to `(N-1,M-1)`.
    pub path: Vec<(usize, usize)>,
}

impl DtwResult {
    /// Build `Y'` — `y` warped onto `x`'s time axis (paper §3.1.2: "Y' is
    /// always made from Y by repeating some of its elements"): for each `i`,
    /// the `y` sample the optimal path last visits in row `i`.
    pub fn warp_onto_x(&self, y: &[f64], n: usize) -> Vec<f64> {
        let mut out = vec![0.0; n];
        for &(i, j) in &self.path {
            out[i] = y[j]; // path is ordered; later visits overwrite
        }
        out
    }
}

/// Compute the DTW cost matrix choices and distance, then backtrack.
///
/// Tie-breaking (shared with the Pallas kernel): the *vertical group*
/// `min(D[i-1,j], D[i-1,j-1])` wins over `D[i,j-1]` (left) on ties, and the
/// diagonal wins over up on ties within the group.
pub fn dtw(x: &[f64], y: &[f64]) -> DtwResult {
    with_thread_scratch(|scratch| dtw_with(scratch, x, y))
}

/// [`dtw`] with caller-provided scratch buffers (bit-identical).
pub fn dtw_with(scratch: &mut DtwScratch, x: &[f64], y: &[f64]) -> DtwResult {
    let (n, m) = (x.len(), y.len());
    assert!(n > 0 && m > 0, "dtw: empty series");
    let mut choices = scratch.choice_buf(n * m, 0u8);
    let mut prev = scratch.row(m, 0.0);
    let mut cur = scratch.row(m, 0.0);

    // Row 0.
    cur[0] = local_cost(x[0], y[0]);
    choices[0] = CHOICE_DIAG; // unused (origin)
    for j in 1..m {
        cur[j] = cur[j - 1] + local_cost(x[0], y[j]);
        choices[j] = CHOICE_LEFT;
    }
    std::mem::swap(&mut prev, &mut cur);

    for i in 1..n {
        let row = i * m;
        cur[0] = prev[0] + local_cost(x[i], y[0]);
        choices[row] = CHOICE_UP;
        for j in 1..m {
            let d = local_cost(x[i], y[j]);
            // Vertical group: diag vs up (diag wins ties).
            let (vg, vchoice) = if prev[j - 1] <= prev[j] {
                (prev[j - 1], CHOICE_DIAG)
            } else {
                (prev[j], CHOICE_UP)
            };
            // Left wins only when strictly smaller than the group.
            if cur[j - 1] < vg {
                cur[j] = cur[j - 1] + d;
                choices[row + j] = CHOICE_LEFT;
            } else {
                cur[j] = vg + d;
                choices[row + j] = vchoice;
            }
        }
        std::mem::swap(&mut prev, &mut cur);
    }

    let distance = prev[m - 1];
    let path = backtrack(&choices, n, m);
    scratch.put_row(prev);
    scratch.put_row(cur);
    scratch.put_choice_buf(choices);
    DtwResult {
        distance,
        normalized: distance / (n + m) as f64,
        path,
    }
}

/// Walk the choice matrix from `(n-1, m-1)` back to `(0,0)`.
/// Shared by the pure-Rust path and the PJRT path (which returns the same
/// choice matrix from the Pallas kernel).
pub fn backtrack(choices: &[u8], n: usize, m: usize) -> Vec<(usize, usize)> {
    debug_assert_eq!(choices.len(), n * m);
    let mut path = Vec::with_capacity(n + m);
    let (mut i, mut j) = (n - 1, m - 1);
    loop {
        path.push((i, j));
        if i == 0 && j == 0 {
            break;
        }
        if i == 0 {
            j -= 1;
            continue;
        }
        if j == 0 {
            i -= 1;
            continue;
        }
        match choices[i * m + j] {
            CHOICE_DIAG => {
                i -= 1;
                j -= 1;
            }
            CHOICE_UP => i -= 1,
            CHOICE_LEFT => j -= 1,
            c => unreachable!("bad choice {c}"),
        }
    }
    path.reverse();
    path
}

/// Distance-only DTW (two rolling rows, no choices) — used by FastDTW's
/// accuracy tests and anywhere the path is not needed.
pub fn dtw_distance(x: &[f64], y: &[f64]) -> f64 {
    with_thread_scratch(|scratch| dtw_distance_with(scratch, x, y))
}

/// [`dtw_distance`] with caller-provided scratch buffers (bit-identical).
pub fn dtw_distance_with(scratch: &mut DtwScratch, x: &[f64], y: &[f64]) -> f64 {
    let (n, m) = (x.len(), y.len());
    assert!(n > 0 && m > 0);
    let mut prev = scratch.row(m, 0.0);
    let mut cur = scratch.row(m, 0.0);
    cur[0] = local_cost(x[0], y[0]);
    for j in 1..m {
        cur[j] = cur[j - 1] + local_cost(x[0], y[j]);
    }
    std::mem::swap(&mut prev, &mut cur);
    for i in 1..n {
        cur[0] = prev[0] + local_cost(x[i], y[0]);
        for j in 1..m {
            let best = prev[j - 1].min(prev[j]).min(cur[j - 1]);
            cur[j] = best + local_cost(x[i], y[j]);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    let distance = prev[m - 1];
    scratch.put_row(prev);
    scratch.put_row(cur);
    distance
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn rand_series(g: &mut Pcg32, len: usize) -> Vec<f64> {
        (0..len).map(|_| g.f64()).collect()
    }

    #[test]
    fn identical_series_distance_zero() {
        let x = vec![0.1, 0.5, 0.3, 0.9];
        let r = dtw(&x, &x);
        assert_eq!(r.distance, 0.0);
        // Path is the main diagonal.
        assert_eq!(r.path, (0..4).map(|i| (i, i)).collect::<Vec<_>>());
    }

    #[test]
    fn known_small_example() {
        // Hand-checked: x=[0,1,2], y=[0,2].
        // D = [[0,2],[1,1],[3,1]] → distance 1.
        let r = dtw(&[0.0, 1.0, 2.0], &[0.0, 2.0]);
        assert_eq!(r.distance, 1.0);
        assert_eq!(r.path.first(), Some(&(0, 0)));
        assert_eq!(r.path.last(), Some(&(2, 1)));
    }

    #[test]
    fn time_shift_is_cheap_for_dtw() {
        // A shifted copy should have a much smaller DTW distance than the
        // pointwise (lock-step) distance — DTW's raison d'être.
        let x: Vec<f64> = (0..100).map(|i| ((i as f64) * 0.2).sin()).collect();
        let y: Vec<f64> = (0..100).map(|i| (((i + 6) as f64) * 0.2).sin()).collect();
        let lockstep: f64 = x.iter().zip(&y).map(|(a, b)| (a - b).abs()).sum();
        let r = dtw(&x, &y);
        assert!(r.distance < lockstep / 4.0, "dtw {} lockstep {}", r.distance, lockstep);
    }

    #[test]
    fn distance_symmetry() {
        let mut g = Pcg32::new(1, 1);
        for _ in 0..20 {
            let lx = 3 + g.below(40) as usize;
            let x = rand_series(&mut g, lx);
            let ly = 3 + g.below(40) as usize;
            let y = rand_series(&mut g, ly);
            let a = dtw(&x, &y).distance;
            let b = dtw(&y, &x).distance;
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn path_is_monotone_and_connected() {
        let mut g = Pcg32::new(2, 7);
        for _ in 0..30 {
            let lx = 2 + g.below(60) as usize;
            let x = rand_series(&mut g, lx);
            let ly = 2 + g.below(60) as usize;
            let y = rand_series(&mut g, ly);
            let r = dtw(&x, &y);
            assert_eq!(r.path.first(), Some(&(0, 0)));
            assert_eq!(r.path.last(), Some(&(x.len() - 1, y.len() - 1)));
            for w in r.path.windows(2) {
                let (i0, j0) = w[0];
                let (i1, j1) = w[1];
                let di = i1 - i0;
                let dj = j1 - j0;
                assert!(di <= 1 && dj <= 1 && di + dj >= 1, "step {w:?}");
            }
        }
    }

    #[test]
    fn path_cost_equals_distance() {
        let mut g = Pcg32::new(3, 3);
        for _ in 0..20 {
            let lx = 2 + g.below(50) as usize;
            let x = rand_series(&mut g, lx);
            let ly = 2 + g.below(50) as usize;
            let y = rand_series(&mut g, ly);
            let r = dtw(&x, &y);
            let cost: f64 = r.path.iter().map(|&(i, j)| local_cost(x[i], y[j])).sum();
            assert!((cost - r.distance).abs() < 1e-9);
        }
    }

    #[test]
    fn distance_only_matches_full() {
        let mut g = Pcg32::new(4, 9);
        for _ in 0..20 {
            let lx = 2 + g.below(50) as usize;
            let x = rand_series(&mut g, lx);
            let ly = 2 + g.below(50) as usize;
            let y = rand_series(&mut g, ly);
            assert!((dtw(&x, &y).distance - dtw_distance(&x, &y)).abs() < 1e-12);
        }
    }

    #[test]
    fn triangle_like_bound_vs_pointwise() {
        // DTW distance never exceeds the lock-step L1 distance for
        // equal-length series (the diagonal is one admissible path).
        let mut g = Pcg32::new(5, 5);
        for _ in 0..20 {
            let n = 2 + g.below(64) as usize;
            let x = rand_series(&mut g, n);
            let y = rand_series(&mut g, n);
            let lockstep: f64 = x.iter().zip(&y).map(|(a, b)| (a - b).abs()).sum();
            assert!(dtw_distance(&x, &y) <= lockstep + 1e-12);
        }
    }

    #[test]
    fn warp_onto_x_repeats_y_elements() {
        let x = vec![0.0, 0.0, 1.0, 2.0, 2.0];
        let y = vec![0.0, 1.0, 2.0];
        let r = dtw(&x, &y);
        let warped = r.warp_onto_x(&y, x.len());
        assert_eq!(warped.len(), x.len());
        // Every warped value is an element of y.
        for v in &warped {
            assert!(y.contains(v));
        }
        // For this construction the warp is exact.
        assert_eq!(warped, x);
    }

    #[test]
    fn distance_zero_iff_identical_after_warp() {
        // x and its "stuttered" version warp to distance 0.
        let x = vec![0.1, 0.4, 0.8, 0.3];
        let y = vec![0.1, 0.1, 0.4, 0.8, 0.8, 0.8, 0.3];
        let r = dtw(&x, &y);
        assert!(r.distance.abs() < 1e-12);
    }
}
