//! FastDTW (Salvador & Chan 2007) — the paper's reference [20], cited as the
//! answer to DTW's quadratic cost in the cluster-scale future-work section.
//!
//! Multiresolution scheme: coarsen both series by 2, solve recursively,
//! project the coarse path onto the finer grid, and re-solve inside a
//! window of the projection expanded by `radius`.
//!
//! All temporaries — the O(log n) coarsened copies, the per-level window,
//! and the windowed DP's rows/choices — come from a [`DtwScratch`] pool,
//! so repeated calls stop allocating once the pool has grown to the
//! deepest recursion seen.

use super::full::{dtw_with, DtwResult};
use super::scratch::{with_thread_scratch, DtwScratch};
use super::{local_cost, CHOICE_DIAG, CHOICE_LEFT, CHOICE_UP};

/// FastDTW with the given radius. Larger radius → closer to exact, slower.
pub fn fastdtw(x: &[f64], y: &[f64], radius: usize) -> DtwResult {
    with_thread_scratch(|scratch| fastdtw_with(scratch, x, y, radius))
}

/// [`fastdtw`] with caller-provided scratch buffers (bit-identical).
pub fn fastdtw_with(scratch: &mut DtwScratch, x: &[f64], y: &[f64], radius: usize) -> DtwResult {
    let min_size = radius + 2;
    if x.len() <= min_size || y.len() <= min_size {
        return dtw_with(scratch, x, y);
    }
    let mut xs = scratch.raw_row();
    coarsen_into(x, &mut xs);
    let mut ys = scratch.raw_row();
    coarsen_into(y, &mut ys);
    let coarse = fastdtw_with(scratch, &xs, &ys, radius);
    scratch.put_row(xs);
    scratch.put_row(ys);
    let mut window = scratch.range_buf();
    expand_window_into(&coarse.path, x.len(), y.len(), radius, &mut window);
    let out = windowed_dtw_with(scratch, x, y, &window);
    scratch.put_range_buf(window);
    out
}

/// Halve resolution by averaging adjacent pairs (odd tail carried over),
/// writing into a reusable buffer.
fn coarsen_into(xs: &[f64], out: &mut Vec<f64>) {
    out.clear();
    let mut i = 0;
    while i + 1 < xs.len() {
        out.push(0.5 * (xs[i] + xs[i + 1]));
        i += 2;
    }
    if i < xs.len() {
        out.push(xs[i]);
    }
}

/// Project a coarse path to the finer grid and expand by `radius`;
/// fills `window` with per-row inclusive `(lo, hi)` j-ranges, made
/// monotone/connected.
fn expand_window_into(
    coarse_path: &[(usize, usize)],
    n: usize,
    m: usize,
    radius: usize,
    window: &mut Vec<(usize, usize)>,
) {
    window.clear();
    window.resize(n, (usize::MAX, 0));
    {
        let mut mark = |i: usize, j: usize| {
            if i < n {
                let jlo = j.saturating_sub(radius);
                let jhi = (j + radius).min(m - 1);
                window[i].0 = window[i].0.min(jlo);
                window[i].1 = window[i].1.max(jhi);
            }
        };
        for &(ci, cj) in coarse_path {
            // Each coarse cell covers a 2×2 block of fine cells.
            for di in 0..2 {
                for dj in 0..2 {
                    let i = 2 * ci + di;
                    let j = (2 * cj + dj).min(m - 1);
                    // Expand by radius in i as well by marking neighbours.
                    let ilo = i.saturating_sub(radius);
                    let ihi = (i + radius).min(n - 1);
                    for ii in ilo..=ihi {
                        mark(ii, j);
                    }
                }
            }
        }
    }
    // Fill any unreached rows (possible with degenerate coarse paths) and
    // enforce per-row connectivity with the previous row.
    let mut prev_hi = 0usize;
    for i in 0..n {
        if window[i].0 == usize::MAX {
            window[i] = (prev_hi, prev_hi);
        }
        // A legal step needs overlap or adjacency with the previous row.
        if window[i].0 > prev_hi {
            window[i].0 = prev_hi;
        }
        if window[i].1 < window[i].0 {
            window[i].1 = window[i].0;
        }
        prev_hi = window[i].1;
    }
    window[0].0 = 0;
    window[n - 1].1 = m - 1;
}

/// DTW restricted to per-row `(lo, hi)` windows.
fn windowed_dtw_with(
    scratch: &mut DtwScratch,
    x: &[f64],
    y: &[f64],
    window: &[(usize, usize)],
) -> DtwResult {
    let (n, m) = (x.len(), y.len());
    let inf = f64::INFINITY;
    let mut choices = scratch.choice_buf(n * m, CHOICE_DIAG);
    let mut prev = scratch.row(m, inf);
    let mut cur = scratch.row(m, inf);

    let (lo0, hi0) = window[0];
    cur[lo0] = local_cost(x[0], y[lo0]);
    for j in (lo0 + 1)..=hi0 {
        cur[j] = cur[j - 1] + local_cost(x[0], y[j]);
        choices[j] = CHOICE_LEFT;
    }
    std::mem::swap(&mut prev, &mut cur);

    for i in 1..n {
        let (lo, hi) = window[i];
        let row = i * m;
        cur.iter_mut().for_each(|v| *v = inf);
        for j in lo..=hi {
            let d = local_cost(x[i], y[j]);
            let diag = if j > 0 { prev[j - 1] } else { inf };
            let up = prev[j];
            let left = if j > lo { cur[j - 1] } else { inf };
            let (vg, vchoice) = if diag <= up { (diag, CHOICE_DIAG) } else { (up, CHOICE_UP) };
            if left < vg {
                cur[j] = left + d;
                choices[row + j] = CHOICE_LEFT;
            } else {
                cur[j] = vg + d;
                choices[row + j] = vchoice;
            }
        }
        std::mem::swap(&mut prev, &mut cur);
    }

    let distance = prev[m - 1];
    assert!(distance.is_finite(), "window disconnected");
    let path = super::full::backtrack(&choices, n, m);
    scratch.put_row(prev);
    scratch.put_row(cur);
    scratch.put_choice_buf(choices);
    DtwResult {
        distance,
        normalized: distance / (n + m) as f64,
        path,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtw::full::dtw_distance;
    use crate::util::rng::Pcg32;

    fn rand_walk(g: &mut Pcg32, len: usize) -> Vec<f64> {
        let mut v = 0.5;
        (0..len)
            .map(|_| {
                v = (v + (g.f64() - 0.5) * 0.1).clamp(0.0, 1.0);
                v
            })
            .collect()
    }

    #[test]
    fn small_inputs_are_exact() {
        let mut g = Pcg32::new(20, 1);
        for _ in 0..10 {
            let lx = 2 + g.below(10) as usize;
            let x = rand_walk(&mut g, lx);
            let ly = 2 + g.below(10) as usize;
            let y = rand_walk(&mut g, ly);
            let exact = dtw_distance(&x, &y);
            let fast = fastdtw(&x, &y, 8).distance;
            assert!((exact - fast).abs() < 1e-12);
        }
    }

    #[test]
    fn approximation_error_small_on_smooth_series() {
        let mut g = Pcg32::new(21, 2);
        let mut errs = Vec::new();
        for _ in 0..10 {
            let lx = 200 + g.below(100) as usize;
            let x = rand_walk(&mut g, lx);
            let ly = 200 + g.below(100) as usize;
            let y = rand_walk(&mut g, ly);
            let exact = dtw_distance(&x, &y);
            let fast = fastdtw(&x, &y, 10).distance;
            assert!(fast >= exact - 1e-9, "fastdtw below exact");
            let rel = if exact > 1e-9 { (fast - exact) / exact } else { 0.0 };
            errs.push(rel);
        }
        let mean_err = crate::util::stats::mean(&errs);
        assert!(mean_err < 0.05, "mean relative error {mean_err}");
    }

    #[test]
    fn identical_series_zero() {
        let x: Vec<f64> = (0..500).map(|i| ((i as f64) * 0.05).sin()).collect();
        let r = fastdtw(&x, &x, 3);
        assert!(r.distance.abs() < 1e-12);
    }

    #[test]
    fn path_endpoints_valid() {
        let mut g = Pcg32::new(22, 3);
        let x = rand_walk(&mut g, 333);
        let y = rand_walk(&mut g, 257);
        let r = fastdtw(&x, &y, 5);
        assert_eq!(r.path.first(), Some(&(0, 0)));
        assert_eq!(r.path.last(), Some(&(332, 256)));
        for w in r.path.windows(2) {
            let (i0, j0) = w[0];
            let (i1, j1) = w[1];
            assert!(i1 - i0 <= 1 && j1 - j0 <= 1 && (i1 - i0) + (j1 - j0) >= 1);
        }
    }

    #[test]
    fn larger_radius_is_no_worse() {
        let mut g = Pcg32::new(23, 4);
        let x = rand_walk(&mut g, 400);
        let y = rand_walk(&mut g, 380);
        let d1 = fastdtw(&x, &y, 1).distance;
        let d20 = fastdtw(&x, &y, 20).distance;
        assert!(d20 <= d1 + 1e-9, "r=20 {d20} > r=1 {d1}");
    }

    #[test]
    fn coarsen_halves_and_averages() {
        let mut out = Vec::new();
        coarsen_into(&[1.0, 3.0, 5.0, 7.0], &mut out);
        assert_eq!(out, vec![2.0, 6.0]);
        coarsen_into(&[1.0, 3.0, 9.0], &mut out);
        assert_eq!(out, vec![2.0, 9.0]);
    }

    #[test]
    fn pooled_scratch_matches_fresh_scratch() {
        let mut g = Pcg32::new(24, 5);
        let mut warm = DtwScratch::new();
        for _ in 0..5 {
            let x = rand_walk(&mut g, 150 + g.below(150) as usize);
            let y = rand_walk(&mut g, 150 + g.below(150) as usize);
            let a = fastdtw_with(&mut warm, &x, &y, 6);
            let b = fastdtw_with(&mut DtwScratch::new(), &x, &y, 6);
            assert_eq!(a.distance.to_bits(), b.distance.to_bits());
            assert_eq!(a.path, b.path);
        }
    }
}
