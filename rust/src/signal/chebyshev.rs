//! Chebyshev type-I low-pass filter design and cascaded-biquad filtering.
//!
//! Implements the classic design chain — analog prototype poles → low-pass
//! frequency scaling with pre-warping → bilinear transform → second-order
//! sections — with no external DSP dependency. The design is pinned against
//! `scipy.signal.cheby1(6, 0.5, 0.1, output='sos')` golden values in the
//! tests below, and the same golden coefficients pin the Python/Pallas
//! implementation, so all three layers filter identically.

/// Complex number helper (no `num-complex` offline; only what design needs).
#[derive(Debug, Clone, Copy, PartialEq)]
struct C {
    re: f64,
    im: f64,
}

impl C {
    fn new(re: f64, im: f64) -> C {
        C { re, im }
    }

    fn add(self, o: C) -> C {
        C::new(self.re + o.re, self.im + o.im)
    }

    fn sub(self, o: C) -> C {
        C::new(self.re - o.re, self.im - o.im)
    }

    fn mul(self, o: C) -> C {
        C::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }

    fn div(self, o: C) -> C {
        let d = o.re * o.re + o.im * o.im;
        C::new(
            (self.re * o.re + self.im * o.im) / d,
            (self.im * o.re - self.re * o.im) / d,
        )
    }

    fn scale(self, k: f64) -> C {
        C::new(self.re * k, self.im * k)
    }

    fn abs2(self) -> f64 {
        self.re * self.re + self.im * self.im
    }
}

/// One second-order section: `b = [b0,b1,b2]`, `a = [1,a1,a2]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Biquad {
    pub b: [f64; 3],
    pub a1: f64,
    pub a2: f64,
}

/// A cascade of second-order sections (SOS) — the filter.
#[derive(Debug, Clone, PartialEq)]
pub struct Sos {
    pub sections: Vec<Biquad>,
}

impl Sos {
    /// Design an even-order Chebyshev type-I low-pass filter.
    ///
    /// * `order` — filter order (must be even and ≥ 2; the paper uses 6).
    /// * `ripple_db` — pass-band ripple in dB (> 0).
    /// * `cutoff` — cutoff as a fraction of the Nyquist frequency, in (0,1).
    pub fn cheby1_lowpass(order: usize, ripple_db: f64, cutoff: f64) -> Sos {
        assert!(order >= 2 && order % 2 == 0, "even order >= 2 required");
        assert!(ripple_db > 0.0, "ripple must be positive");
        assert!(cutoff > 0.0 && cutoff < 1.0, "cutoff in (0,1) of Nyquist");

        let n = order;
        // Analog prototype (cutoff 1 rad/s).
        let eps = (10f64.powf(ripple_db / 10.0) - 1.0).sqrt();
        let mu = (1.0 / eps).asinh() / n as f64;
        let sinh_mu = mu.sinh();
        let cosh_mu = mu.cosh();
        let mut poles: Vec<C> = (1..=n)
            .map(|k| {
                let theta = std::f64::consts::PI * (2 * k as i64 - 1) as f64 / (2.0 * n as f64);
                C::new(-sinh_mu * theta.sin(), cosh_mu * theta.cos())
            })
            .collect();
        // Prototype gain: product of (-poles); even order divides by sqrt(1+eps^2).
        let mut k0 = C::new(1.0, 0.0);
        for p in &poles {
            k0 = k0.mul(p.scale(-1.0));
        }
        let mut gain = k0.re / (1.0 + eps * eps).sqrt();

        // Low-pass scale with bilinear pre-warping (fs = 2 convention).
        let fs2 = 4.0; // 2 * fs
        let warped = fs2 * (std::f64::consts::PI * cutoff / 2.0).tan();
        for p in &mut poles {
            *p = p.scale(warped);
        }
        gain *= warped.powi(n as i32);

        // Bilinear transform: z = (fs2 + s) / (fs2 - s); n zeros at z = -1.
        let mut zpoles = Vec::with_capacity(n);
        let mut denom = C::new(1.0, 0.0);
        for p in &poles {
            zpoles.push(C::new(fs2, 0.0).add(*p).div(C::new(fs2, 0.0).sub(*p)));
            denom = denom.mul(C::new(fs2, 0.0).sub(*p));
        }
        // Imaginary parts cancel over conjugate pairs.
        let gz = gain / denom.re;

        // Pair conjugates into biquads; sort by pole radius so section order
        // matches scipy's (ascending |p|² keeps the golden comparison exact).
        let mut pairs: Vec<(C, f64)> = zpoles
            .iter()
            .filter(|p| p.im > 0.0)
            .map(|p| (*p, p.abs2()))
            .collect();
        pairs.sort_by(|x, y| x.1.partial_cmp(&y.1).expect("finite radius"));
        let mut sections: Vec<Biquad> = pairs
            .iter()
            .map(|(p, r2)| Biquad {
                b: [1.0, 2.0, 1.0],
                a1: -2.0 * p.re,
                a2: *r2,
            })
            .collect();
        // Fold the overall gain into the first section (scipy layout).
        for c in &mut sections[0].b {
            *c *= gz;
        }
        Sos { sections }
    }

    /// The paper's filter: 6th order, 0.5 dB ripple, 0.1 × Nyquist cutoff
    /// (1 Hz sampling → 0.05 Hz cutoff, well below the map/reduce phase
    /// structure but above the SysStat sampling noise).
    pub fn lowpass_default() -> Sos {
        Sos::cheby1_lowpass(6, 0.5, 0.1)
    }

    /// Run the cascade over `x` (Direct Form II transposed per section),
    /// zero initial state — matches `scipy.signal.sosfilt`.
    pub fn filter(&self, x: &[f64]) -> Vec<f64> {
        let mut y: Vec<f64> = x.to_vec();
        for s in &self.sections {
            let mut s1 = 0.0;
            let mut s2 = 0.0;
            for v in y.iter_mut() {
                let xin = *v;
                let yo = s.b[0] * xin + s1;
                s1 = s.b[1] * xin - s.a1 * yo + s2;
                s2 = s.b[2] * xin - s.a2 * yo;
                *v = yo;
            }
        }
        y
    }

    /// DC gain of the cascade (`H(z=1)`).
    pub fn dc_gain(&self) -> f64 {
        self.sections
            .iter()
            .map(|s| (s.b[0] + s.b[1] + s.b[2]) / (1.0 + s.a1 + s.a2))
            .product()
    }

    /// True if every pole is strictly inside the unit circle.
    pub fn is_stable(&self) -> bool {
        self.sections.iter().all(|s| s.a2 < 1.0 && s.a1.abs() < 1.0 + s.a2)
    }

    /// Start a causal streaming run of this cascade (zero initial state).
    /// Pushing a series sample-by-sample produces **bit-identical** output
    /// to [`Sos::filter`] on the whole series: each section is causal, so
    /// per-sample cascade order and per-section batch order perform the
    /// same arithmetic in the same sequence. This is what lets the
    /// streaming classifier filter a live CPU capture incrementally while
    /// guaranteeing the completed prefix equals the batch-preprocessed
    /// series.
    pub fn stream(&self) -> SosState {
        SosState {
            sections: self.sections.clone(),
            state: vec![(0.0, 0.0); self.sections.len()],
        }
    }

    /// Conservative bounds on any output sample of this cascade for inputs
    /// confined to `[input_lo, input_hi]`, from the truncated impulse
    /// response: `y_t = Σ h_k · x_{t-k}`, so `y_t` is bounded by summing
    /// each tap against whichever input extreme it favours. `horizon` is
    /// the truncation length; the default filter's impulse response decays
    /// below 1e-12 well within 1024 samples, and both bounds include `0`
    /// per tap, so they also cover the partial sums of the zero-state
    /// start-up. Used by the streaming prefix bounds to cap where the
    /// running min/max of a filtered live capture can still go.
    pub fn output_bounds(&self, input_lo: f64, input_hi: f64, horizon: usize) -> (f64, f64) {
        assert!(input_lo <= input_hi, "output_bounds: inverted input range");
        let mut impulse = vec![0.0; horizon.max(1)];
        impulse[0] = 1.0;
        let h = self.filter(&impulse);
        let mut lo = 0.0;
        let mut hi = 0.0;
        for &hk in &h {
            lo += (hk * input_lo).min(hk * input_hi).min(0.0);
            hi += (hk * input_lo).max(hk * input_hi).max(0.0);
        }
        (lo, hi)
    }
}

/// Streaming state of one [`Sos`] cascade: per-section Direct Form II
/// transposed delay registers. Created by [`Sos::stream`].
#[derive(Debug, Clone)]
pub struct SosState {
    sections: Vec<Biquad>,
    /// `(s1, s2)` per section.
    state: Vec<(f64, f64)>,
}

impl SosState {
    /// Filter one sample through the cascade.
    pub fn push(&mut self, x: f64) -> f64 {
        let mut v = x;
        for (sec, st) in self.sections.iter().zip(self.state.iter_mut()) {
            let yo = sec.b[0] * v + st.0;
            st.0 = sec.b[1] * v - sec.a1 * yo + st.1;
            st.1 = sec.b[2] * v - sec.a2 * yo;
            v = yo;
        }
        v
    }

    /// Filter a batch of samples, appending the outputs to `out`.
    pub fn extend(&mut self, xs: &[f64], out: &mut Vec<f64>) {
        out.reserve(xs.len());
        for &x in xs {
            let y = self.push(x);
            out.push(y);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// scipy.signal.cheby1(6, 0.5, 0.1, output='sos') — golden.
    const SCIPY_SOS: [[f64; 6]; 3] = [
        [
            1.1341790241947333e-06,
            2.2683580483894666e-06,
            1.1341790241947333e-06,
            1.0,
            -1.8180684439942343,
            0.8324455519809297,
        ],
        [1.0, 2.0, 1.0, 1.0, -1.8210683354520127, 0.8757846277694602],
        [1.0, 2.0, 1.0, 1.0, -1.8554197031915467, 0.9531599405224532],
    ];

    /// scipy.signal.sosfilt(sos, ones(16)) — golden step response.
    const SCIPY_STEP: [f64; 16] = [
        1.1341790241947333e-06,
        1.4171063879224112e-05,
        8.838396641944708e-05,
        0.0003709700620232489,
        0.001190711211134303,
        0.0031429384633369145,
        0.0071484765005884136,
        0.014465070330996619,
        0.02663942081325119,
        0.045398430261593216,
        0.07248827546206923,
        0.10947831787798826,
        0.15755272207399354,
        0.21731541322510559,
        0.2886334702988405,
        0.37054040669980676,
    ];

    #[test]
    fn design_matches_scipy() {
        let sos = Sos::lowpass_default();
        assert_eq!(sos.sections.len(), 3);
        for (sec, gold) in sos.sections.iter().zip(SCIPY_SOS.iter()) {
            for (i, b) in sec.b.iter().enumerate() {
                assert!((b - gold[i]).abs() < 1e-12, "b[{i}]: {b} vs {}", gold[i]);
            }
            assert!((sec.a1 - gold[4]).abs() < 1e-12, "a1 {} vs {}", sec.a1, gold[4]);
            assert!((sec.a2 - gold[5]).abs() < 1e-12, "a2 {} vs {}", sec.a2, gold[5]);
        }
    }

    #[test]
    fn step_response_matches_scipy() {
        let sos = Sos::lowpass_default();
        let y = sos.filter(&[1.0; 16]);
        for (a, b) in y.iter().zip(SCIPY_STEP.iter()) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn dc_gain_is_ripple_bound() {
        // Even-order type-I: |H(0)| = 1/sqrt(1+eps^2).
        let sos = Sos::lowpass_default();
        let eps = (10f64.powf(0.5 / 10.0) - 1.0).sqrt();
        let want = 1.0 / (1.0 + eps * eps).sqrt();
        assert!((sos.dc_gain() - want).abs() < 1e-9, "{}", sos.dc_gain());
    }

    #[test]
    fn streaming_filter_is_bit_identical_to_batch() {
        let sos = Sos::lowpass_default();
        let x: Vec<f64> = (0..500)
            .map(|i| 0.5 + 0.4 * ((i as f64) * 0.21).sin() + 0.05 * ((i as f64) * 1.7).cos())
            .collect();
        let batch = sos.filter(&x);
        let mut st = sos.stream();
        let mut streamed = Vec::new();
        // Mixed push/extend batching must not matter.
        streamed.push(st.push(x[0]));
        st.extend(&x[1..7], &mut streamed);
        st.extend(&x[7..], &mut streamed);
        assert_eq!(batch.len(), streamed.len());
        for (a, b) in batch.iter().zip(&streamed) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
    }

    #[test]
    fn output_bounds_contain_all_outputs() {
        let sos = Sos::lowpass_default();
        let (lo, hi) = sos.output_bounds(0.0, 1.0, 1024);
        assert!(lo <= 0.0 && hi >= sos.dc_gain(), "lo={lo} hi={hi}");
        // Adversarial bounded inputs: square waves at several periods try
        // to pump the transient; outputs must stay inside the bounds.
        for period in [2usize, 5, 11, 40] {
            let x: Vec<f64> = (0..800)
                .map(|i| if (i / period) % 2 == 0 { 1.0 } else { 0.0 })
                .collect();
            for v in sos.filter(&x) {
                assert!(lo <= v && v <= hi, "period {period}: {v} outside [{lo},{hi}]");
            }
        }
        // The bounds are tight-ish: well inside [-1, 2] for a unit input.
        assert!(lo > -1.0 && hi < 2.0, "suspiciously loose: [{lo},{hi}]");
    }

    #[test]
    fn stable_across_design_space() {
        for order in [2usize, 4, 6, 8] {
            for ripple in [0.1, 0.5, 1.0, 3.0] {
                for cutoff in [0.02, 0.1, 0.25, 0.5, 0.8] {
                    let sos = Sos::cheby1_lowpass(order, ripple, cutoff);
                    assert!(
                        sos.is_stable(),
                        "unstable: order={order} ripple={ripple} cutoff={cutoff}"
                    );
                }
            }
        }
    }

    #[test]
    fn attenuates_high_frequency() {
        // A Nyquist-rate alternating signal must be crushed; a slow ramp passes.
        let sos = Sos::lowpass_default();
        let n = 400;
        let hf: Vec<f64> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let y = sos.filter(&hf);
        let tail_energy: f64 = y[n - 50..].iter().map(|v| v * v).sum::<f64>() / 50.0;
        assert!(tail_energy < 1e-10, "hf energy {tail_energy}");

        let steady = sos.filter(&vec![1.0; 600]);
        assert!((steady[599] - sos.dc_gain()).abs() < 1e-6);
    }

    #[test]
    fn filter_is_linear() {
        let sos = Sos::lowpass_default();
        let x1: Vec<f64> = (0..64).map(|i| (i as f64 * 0.3).sin()).collect();
        let x2: Vec<f64> = (0..64).map(|i| (i as f64 * 0.11).cos()).collect();
        let sum: Vec<f64> = x1.iter().zip(&x2).map(|(a, b)| 2.0 * a + 3.0 * b).collect();
        let y1 = sos.filter(&x1);
        let y2 = sos.filter(&x2);
        let ysum = sos.filter(&sum);
        for i in 0..64 {
            assert!((ysum[i] - (2.0 * y1[i] + 3.0 * y2[i])).abs() < 1e-10);
        }
    }

    #[test]
    #[should_panic(expected = "even order")]
    fn odd_order_rejected() {
        let _ = Sos::cheby1_lowpass(5, 0.5, 0.1);
    }
}
