//! The wire surface as Rust types: protocol v2 envelope, typed
//! requests/responses, and error codes.
//!
//! The service protocol is line-delimited JSON over TCP. Version 2 wraps
//! every request in a small envelope:
//!
//! ```text
//! {"v":2,"id":7,"type":"knn","series":[..],"k":3}
//! ```
//!
//! and every response in a matching one:
//!
//! ```text
//! {"body":{..},"id":7,"ok":true,"type":"knn","v":2}
//! {"error":{"code":"bad_request","message":".."},"id":7,"ok":false,"v":2}
//! ```
//!
//! * `v` pins the protocol version — a line carrying any other version is
//!   answered with [`ErrorCode::WrongVersion`], never silently misparsed.
//! * `id` is chosen by the client and echoed verbatim, which is what makes
//!   pipelining safe: a client may write many requests before reading any
//!   response and match replies by id ([`crate::client::MrtunerClient`]
//!   does exactly this).
//! * `type` selects the command; the remaining fields are the command's
//!   parameters, flat beside the envelope keys.
//! * `trace` (optional) carries the sender's span id so the receiver's
//!   spans nest under it in a merged timeline (see `OBSERVABILITY.md`).
//!   Absent by default — requests without it and all replies are
//!   byte-identical to pre-trace traffic.
//!
//! **v1 compatibility:** any line *without* a `"v"` key is decoded as the
//! legacy `{"cmd": ...}` command set and answered in the legacy shapes
//! (`{"ok":true,...}` / `{"error":"...","ok":false}`), byte-compatibly —
//! pinned by the golden tests in `rust/tests/server_protocol.rs`. Both
//! paths parse into the same [`Request`] enum and render from the same
//! [`Response`] enum; only the envelope and the error rendering differ.
//! See `PROTOCOL.md` at the repository root for the full surface.
//!
//! Everything here converts to/from [`crate::util::json::Json`] by hand —
//! no serde — so the wire shapes are explicit and the round-trip property
//! tests in [`request`] / [`response`] pin them.

pub mod request;
pub mod response;

pub use request::Request;
pub use response::{
    DecisionBody, FinalBody, KnnBatchBody, KnnBody, MatchBody, MatchRow, NeighborRow, Response,
    SessionPollBody, ShardInfoBody, StatsBody, StreamCloseBody, StreamFeedBody, StreamOpenBody,
    StreamPollBody, StreamTunedBody, TopRow,
};

use crate::util::json::Json;

/// The protocol version this build speaks (and the only one it accepts in
/// a `"v"` envelope; versionless lines take the v1 compatibility path).
pub const PROTOCOL_VERSION: u64 = 2;

/// Largest accepted `knn_batch` request — bounds per-request work the same
/// way `k` is clamped.
pub const MAX_KNN_BATCH: usize = 256;

/// Upper clamp on `k` for `knn`/`knn_batch` requests.
pub const MAX_K: usize = 100;

/// Upper clamp on `k` for `stream_poll`/`stream_poll_all` requests.
pub const MAX_POLL_K: usize = 20;

/// Machine-readable error classes. The string forms are wire-stable: v2
/// error responses carry them in `error.code`, and
/// [`crate::coordinator::metrics::Metrics`] counts rejects per code.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Malformed JSON, missing/invalid fields, or an unroutable command.
    BadRequest,
    /// The `cmd`/`type` names no known command.
    UnknownCommand,
    /// A `stream_*` request named a session id that is not (or no longer)
    /// registered.
    UnknownSession,
    /// The `"v"` envelope carried a version this server does not speak.
    WrongVersion,
    /// The request exceeded a size bound (batch width, line length).
    TooLarge,
    /// A shard behind the router could not be reached or answered
    /// malformed data.
    ShardUnavailable,
    /// Unexpected server-side failure.
    Internal,
    /// The request's `deadline_ms` budget expired before an answer was
    /// assembled (fan-out still in flight, or a retry would overrun it).
    DeadlineExceeded,
}

impl ErrorCode {
    /// Every code, in metrics-index order.
    pub const ALL: [ErrorCode; 8] = [
        ErrorCode::BadRequest,
        ErrorCode::UnknownCommand,
        ErrorCode::UnknownSession,
        ErrorCode::WrongVersion,
        ErrorCode::TooLarge,
        ErrorCode::ShardUnavailable,
        ErrorCode::Internal,
        ErrorCode::DeadlineExceeded,
    ];

    /// Stable wire string.
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnknownCommand => "unknown_command",
            ErrorCode::UnknownSession => "unknown_session",
            ErrorCode::WrongVersion => "wrong_version",
            ErrorCode::TooLarge => "too_large",
            ErrorCode::ShardUnavailable => "shard_unavailable",
            ErrorCode::Internal => "internal",
            ErrorCode::DeadlineExceeded => "deadline_exceeded",
        }
    }

    /// Inverse of [`ErrorCode::as_str`].
    pub fn parse(s: &str) -> Option<ErrorCode> {
        ErrorCode::ALL.iter().copied().find(|c| c.as_str() == s)
    }

    /// Dense index (for per-code metric counters). Matches the order of
    /// [`ErrorCode::ALL`] by construction.
    pub fn index(self) -> usize {
        match self {
            ErrorCode::BadRequest => 0,
            ErrorCode::UnknownCommand => 1,
            ErrorCode::UnknownSession => 2,
            ErrorCode::WrongVersion => 3,
            ErrorCode::TooLarge => 4,
            ErrorCode::ShardUnavailable => 5,
            ErrorCode::Internal => 6,
            ErrorCode::DeadlineExceeded => 7,
        }
    }
}

impl std::fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A typed protocol error: machine-readable code + human message.
#[derive(Debug, Clone, PartialEq)]
pub struct ServerError {
    pub code: ErrorCode,
    pub message: String,
}

impl ServerError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> ServerError {
        ServerError {
            code,
            message: message.into(),
        }
    }

    /// The workhorse constructor: malformed/missing fields.
    pub fn bad_request(message: impl Into<String>) -> ServerError {
        ServerError::new(ErrorCode::BadRequest, message)
    }
}

impl std::fmt::Display for ServerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code.as_str(), self.message)
    }
}

impl std::error::Error for ServerError {}

/// Which envelope a request line arrived in — decides how its reply is
/// rendered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Wire {
    /// Legacy versionless `{"cmd": ...}` line.
    V1,
    /// Protocol v2 envelope; `id` is echoed into the reply. `trace` is
    /// the optional trace-propagation field (0 when absent): the sender's
    /// span id, recorded by the receiver as its root span's remote
    /// parent so both sides' trees merge into one timeline. `deadline_ms`
    /// is the optional per-request time budget: the handling side (today
    /// the router's fan-out) stops waiting once it expires and answers
    /// [`ErrorCode::DeadlineExceeded`]; absent means no budget — exactly
    /// today's behavior. Replies never carry either field, and requests
    /// without them are byte-identical to pre-trace traffic.
    V2 {
        id: u64,
        trace: u64,
        deadline_ms: Option<u64>,
    },
}

/// Decode one request line into its envelope flavor and (if well-formed)
/// the typed [`Request`]. Never panics, whatever the bytes: parse failures
/// come back as a [`ServerError`] paired with the envelope the reply must
/// use. Both the match server and the shard router build their read loops
/// on this.
pub fn decode_line(line: &str) -> (Wire, Result<Request, ServerError>) {
    let req = match Json::parse(line) {
        Ok(r) => r,
        Err(e) => {
            // Without a parse we cannot know the envelope; legacy error
            // rendering is the conservative answer (v1 clients predate
            // envelopes, v2 clients tolerate it by construction).
            return (Wire::V1, Err(ServerError::bad_request(format!("bad json: {e}"))));
        }
    };
    match req.get("v") {
        None => (Wire::V1, Request::from_v1(&req)),
        Some(v) => {
            let id = req.get("id").and_then(Json::as_u64).unwrap_or(0);
            let trace = req.get("trace").and_then(Json::as_u64).unwrap_or(0);
            let deadline_ms = req.get("deadline_ms").and_then(Json::as_u64);
            let wire = Wire::V2 {
                id,
                trace,
                deadline_ms,
            };
            if v.as_f64() != Some(PROTOCOL_VERSION as f64) {
                let err = ServerError::new(
                    ErrorCode::WrongVersion,
                    format!("unsupported protocol version {v} (this server speaks v{PROTOCOL_VERSION})"),
                );
                (wire, Err(err))
            } else if req.get("id").and_then(Json::as_u64).is_none() {
                (wire, Err(ServerError::bad_request("missing request id")))
            } else {
                (wire, Request::from_v2(&req))
            }
        }
    }
}

/// Render a dispatch outcome into the reply shape `wire` demands.
pub fn encode_reply(wire: &Wire, result: &Result<Response, ServerError>) -> Json {
    match (wire, result) {
        (Wire::V1, Ok(resp)) => resp.to_v1(),
        (Wire::V1, Err(e)) => Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::Str(e.message.clone())),
        ]),
        (Wire::V2 { id, .. }, Ok(resp)) => Json::obj(vec![
            ("v", Json::Num(PROTOCOL_VERSION as f64)),
            ("id", Json::Num(*id as f64)),
            ("ok", Json::Bool(true)),
            ("type", Json::Str(resp.type_name().to_string())),
            ("body", resp.to_body_json()),
        ]),
        (Wire::V2 { id, .. }, Err(e)) => Json::obj(vec![
            ("v", Json::Num(PROTOCOL_VERSION as f64)),
            ("id", Json::Num(*id as f64)),
            ("ok", Json::Bool(false)),
            (
                "error",
                Json::obj(vec![
                    ("code", Json::Str(e.code.as_str().to_string())),
                    ("message", Json::Str(e.message.clone())),
                ]),
            ),
        ]),
    }
}

/// Decode one v2 response line (the client side of [`encode_reply`]):
/// `(id, Ok(response) | Err(typed server error))`, or a description of why
/// the line is not a valid v2 response at all.
pub fn decode_reply(line: &str) -> Result<(u64, Result<Response, ServerError>), String> {
    let v = Json::parse(line).map_err(|e| format!("bad response json: {e}"))?;
    if v.get("v").and_then(Json::as_u64) != Some(PROTOCOL_VERSION) {
        return Err(format!("response is not protocol v{PROTOCOL_VERSION}: {line}"));
    }
    let id = v
        .get("id")
        .and_then(Json::as_u64)
        .ok_or_else(|| "response missing id".to_string())?;
    match v.get("ok").and_then(Json::as_bool) {
        Some(true) => {
            let t = v
                .get("type")
                .and_then(Json::as_str)
                .ok_or_else(|| "response missing type".to_string())?;
            let body = v
                .get("body")
                .ok_or_else(|| "response missing body".to_string())?;
            let resp = Response::from_body(t, body).map_err(|e| format!("bad {t} body: {e}"))?;
            Ok((id, Ok(resp)))
        }
        Some(false) => {
            let eobj = v
                .get("error")
                .ok_or_else(|| "error response missing error object".to_string())?;
            let code = eobj
                .get("code")
                .and_then(Json::as_str)
                .and_then(ErrorCode::parse)
                .unwrap_or(ErrorCode::Internal);
            let msg = eobj
                .get("message")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string();
            Ok((id, Err(ServerError::new(code, msg))))
        }
        None => Err("response missing ok".to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_codes_roundtrip_their_wire_strings() {
        for code in ErrorCode::ALL {
            assert_eq!(ErrorCode::parse(code.as_str()), Some(code));
            assert_eq!(ErrorCode::ALL[code.index()], code);
        }
        assert_eq!(ErrorCode::parse("nope"), None);
    }

    #[test]
    fn decode_line_classifies_envelopes() {
        let (wire, req) = decode_line(r#"{"cmd":"ping"}"#);
        assert_eq!(wire, Wire::V1);
        assert_eq!(req.unwrap(), Request::Ping);

        let (wire, req) = decode_line(r#"{"v":2,"id":9,"type":"ping"}"#);
        assert_eq!(wire, Wire::V2 { id: 9, trace: 0, deadline_ms: None });
        assert_eq!(req.unwrap(), Request::Ping);

        let (wire, req) = decode_line(r#"{"v":2,"id":9,"trace":31,"type":"ping"}"#);
        assert_eq!(wire, Wire::V2 { id: 9, trace: 31, deadline_ms: None });
        assert_eq!(req.unwrap(), Request::Ping);

        let (wire, req) = decode_line(r#"{"v":2,"deadline_ms":250,"id":9,"type":"ping"}"#);
        assert_eq!(
            wire,
            Wire::V2 {
                id: 9,
                trace: 0,
                deadline_ms: Some(250)
            }
        );
        assert_eq!(req.unwrap(), Request::Ping);

        let (wire, req) = decode_line(r#"{"v":3,"id":1,"type":"ping"}"#);
        assert_eq!(wire, Wire::V2 { id: 1, trace: 0, deadline_ms: None });
        assert_eq!(req.unwrap_err().code, ErrorCode::WrongVersion);

        let (wire, req) = decode_line(r#"{"v":2,"type":"ping"}"#);
        assert_eq!(wire, Wire::V2 { id: 0, trace: 0, deadline_ms: None });
        assert_eq!(req.unwrap_err().code, ErrorCode::BadRequest);

        let (wire, req) = decode_line("not json at all");
        assert_eq!(wire, Wire::V1);
        assert_eq!(req.unwrap_err().code, ErrorCode::BadRequest);
    }

    #[test]
    fn v1_error_rendering_is_legacy_shaped() {
        let err = ServerError::bad_request("missing series");
        let line = encode_reply(&Wire::V1, &Err(err)).to_string();
        assert_eq!(line, r#"{"error":"missing series","ok":false}"#);
    }

    #[test]
    fn v2_error_rendering_carries_code_and_id() {
        let err = ServerError::new(ErrorCode::UnknownSession, "unknown session 5");
        let v = encode_reply(&Wire::V2 { id: 12, trace: 0, deadline_ms: None }, &Err(err));
        assert_eq!(v.get("v").and_then(Json::as_u64), Some(2));
        assert_eq!(v.get("id").and_then(Json::as_u64), Some(12));
        assert_eq!(v.get("ok"), Some(&Json::Bool(false)));
        let e = v.get("error").unwrap();
        assert_eq!(e.get("code").and_then(Json::as_str), Some("unknown_session"));
        assert_eq!(e.get("message").and_then(Json::as_str), Some("unknown session 5"));
    }

    #[test]
    fn reply_roundtrip_ok_and_err() {
        let resp = Response::Pong;
        let line = encode_reply(&Wire::V2 { id: 4, trace: 0, deadline_ms: None }, &Ok(resp.clone())).to_string();
        let (id, back) = decode_reply(&line).unwrap();
        assert_eq!(id, 4);
        assert_eq!(back.unwrap(), resp);

        // The trace field influences request decoding only — replies are
        // rendered identically whether or not the request carried one.
        let traced = encode_reply(&Wire::V2 { id: 4, trace: 88, deadline_ms: None }, &Ok(resp.clone())).to_string();
        assert_eq!(traced, line, "replies never echo the trace field");

        let err = ServerError::new(ErrorCode::TooLarge, "batch too large");
        let line = encode_reply(&Wire::V2 { id: 5, trace: 0, deadline_ms: None }, &Err(err.clone())).to_string();
        let (id, back) = decode_reply(&line).unwrap();
        assert_eq!(id, 5);
        assert_eq!(back.unwrap_err(), err);

        assert!(decode_reply("garbage").is_err());
        assert!(decode_reply(r#"{"ok":true}"#).is_err(), "missing v");
    }
}
