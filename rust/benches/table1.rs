//! E1 — regenerate the paper's **Table 1**: similarity of Exim mainlog
//! parsing against WordCount and TeraSort under the four printed
//! configuration sets, plus timing of the full table computation.
//!
//! Run with: `cargo bench --bench table1`

#[path = "harness.rs"]
mod harness;

use mrtuner::coordinator::{matcher::Matcher, print_table1, ConfigGrid, SystemConfig, TuningSystem};
use mrtuner::prelude::*;

fn main() {
    mrtuner::util::logging::init();
    let grid = ConfigGrid::paper_table1();
    let mut sys = TuningSystem::new(SystemConfig::default());
    sys.profile_app(AppId::WordCount, &grid);
    sys.profile_app(AppId::TeraSort, &grid);
    let m = Matcher::new(&sys.config, sys.runtime());

    let table = m.similarity_table(AppId::EximParse, &grid, &sys.db);
    println!("== Table 1 (paper: diag Exim~WC 91.8-94.4%, Exim~TS 58-89%) ==");
    print_table1(&table, &grid);

    // Validation summary (shape, not absolute values).
    let mut diag_ok = 0;
    for q in &grid.configs {
        let wc = table
            .iter()
            .find(|c| {
                c.reference_app == AppId::WordCount
                    && c.reference_config.label() == q.label()
                    && c.config.label() == q.label()
            })
            .unwrap()
            .similarity;
        let ts = table
            .iter()
            .find(|c| {
                c.reference_app == AppId::TeraSort
                    && c.reference_config.label() == q.label()
                    && c.config.label() == q.label()
            })
            .unwrap()
            .similarity;
        if wc >= 90.0 && wc > ts {
            diag_ok += 1;
        }
    }
    println!("\nsame-config cells where WC>=90% and WC>TS: {diag_ok}/4 (paper: 4/4)");

    harness::bench("table1: 8x4 similarity table end-to-end", 1, 5, || {
        m.similarity_table(AppId::EximParse, &grid, &sys.db)
    });
}
