//! [`ShardRouter`]: compose per-config shard servers into one logical
//! reference database, plus [`RouterServer`], the TCP front-end that
//! speaks the same protocol the shards do.
//!
//! Multi-node serving splits the reference database across shard servers
//! (`mrtuner serve --shard-of CONFIGS`), each owning the entries of some
//! configuration sets. The router connects to every shard, learns what
//! each owns through the `shard_info` handshake, and assigns each shard a
//! **global index base** — the running sum of shard entry counts in
//! address order. The composed database is thereby *defined* as the
//! concatenation of the shard databases in that order, and a row's global
//! index is `shard.base + local index`.
//!
//! Fan-out uses the client's pipelining: one request is written to every
//! shard before any reply is read, so shard latencies overlap without
//! threads. Per-shard round trips land in
//! [`Metrics::record_shard_fanout`].
//!
//! **Determinism:** shards answer k-NN with exact per-entry distances (the
//! cascade's cutoffs only ever skip candidates that provably cannot enter
//! the top-k, and distances of returned rows are exact banded-DTW values —
//! independent of what else shares the database). Merging per-shard rows
//! in `(distance, global index)` order is therefore **bit-identical** to a
//! single-node `IndexedDb::knn_batch` over the union database built in the
//! same shard order — same neighbours, same distance bits, same order.
//! Pinned by `rust/tests/shard_router.rs`.
//!
//! Stream sessions are deliberately *not* routed: a session lives on one
//! shard (state and all); a feeder connects to the shard that owns its
//! configuration set. The router rejects `stream_*` with `bad_request`.

use super::metrics::Metrics;
use super::server::{serve_connection_lines, READ_TIMEOUT};
use crate::client::{ClientError, MrtunerClient};
use crate::dtw::corr::MATCH_THRESHOLD;
use crate::index::SearchStats;
use crate::protocol::{
    decode_line, encode_reply, ErrorCode, KnnBatchBody, KnnBody, MatchBody, Request, Response,
    ServerError, ShardInfoBody, StatsBody, Wire,
};
use crate::simulator::job::JobConfig;
use crate::trace::{Span, TraceHandle};
use crate::util::json::Json;
use crate::util::pool::ThreadPool;
use anyhow::Result;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// One connected shard: its client plus what the `shard_info` handshake
/// reported it owns.
pub struct Shard {
    /// Address the router (re)connects to.
    pub addr: String,
    /// Global index base: the sum of entry counts of all earlier shards.
    pub base: usize,
    /// Entries this shard owns.
    pub entries: usize,
    /// Applications present on this shard.
    pub apps: Vec<String>,
    /// Configuration-set labels this shard owns.
    pub configs: Vec<String>,
    client: MrtunerClient,
}

/// Routes `knn` / `knn_batch` / `match` over a fixed set of shards (see
/// module docs for the determinism contract).
pub struct ShardRouter {
    shards: Vec<Shard>,
    metrics: Arc<Metrics>,
    /// Span sink + clock for fan-out tracing; each per-shard round trip
    /// gets a child span whose id rides the envelope's `trace` field, so
    /// shard-side request trees nest under it. Disabled by default.
    tracer: TraceHandle,
}

/// Map a shard-call failure onto the routed error surface: structured
/// shard answers keep their code; transport failures become
/// `shard_unavailable`.
fn shard_err(addr: &str, e: ClientError) -> ClientError {
    match e {
        ClientError::Server(se) => ClientError::Server(se),
        other => ClientError::Server(ServerError::new(
            ErrorCode::ShardUnavailable,
            format!("shard {addr}: {other}"),
        )),
    }
}

/// Read timeout on every shard connection. A shard that stops answering
/// without closing its socket must not wedge the router (routed dispatch
/// serializes on one lock): recv fails after this long and surfaces as
/// `shard_unavailable`. Generous next to real search latencies (ms).
pub const SHARD_REPLY_TIMEOUT: Duration = Duration::from_secs(30);

impl ShardRouter {
    /// Connect to every shard (in the given order — it defines the global
    /// index space) and run the `shard_info` handshake.
    pub fn connect(addrs: &[String], metrics: Arc<Metrics>) -> Result<ShardRouter, ClientError> {
        let mut shards = Vec::with_capacity(addrs.len());
        let mut base = 0usize;
        for addr in addrs {
            let mut client = MrtunerClient::connect_timeout(addr, SHARD_REPLY_TIMEOUT)
                .map_err(|e| shard_err(addr, e))?;
            let info = client.shard_info().map_err(|e| shard_err(addr, e))?;
            log::info!(
                "router: shard {addr} owns {} entries across {} config sets",
                info.entries,
                info.configs.len()
            );
            let entries = info.entries;
            shards.push(Shard {
                addr: addr.clone(),
                base,
                entries,
                apps: info.apps,
                configs: info.configs,
                client,
            });
            base += entries;
        }
        Ok(ShardRouter {
            shards,
            metrics,
            tracer: TraceHandle::disabled(),
        })
    }

    /// Attach a tracer (builder-style; the default router is untraced).
    pub fn with_tracer(mut self, tracer: TraceHandle) -> ShardRouter {
        self.tracer = tracer;
        self
    }

    /// The router's trace handle.
    pub fn tracer(&self) -> &TraceHandle {
        &self.tracer
    }

    /// The connected shards, in global-index order.
    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    /// Entries across all shards (the union database size).
    pub fn total_entries(&self) -> usize {
        self.shards.iter().map(|s| s.entries).sum()
    }

    /// The router's metrics registry (shared with its front-end server).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Union of shard applications, sorted and deduplicated.
    pub fn apps(&self) -> Vec<String> {
        let mut apps: Vec<String> = self
            .shards
            .iter()
            .flat_map(|s| s.apps.iter().cloned())
            .collect();
        apps.sort();
        apps.dedup();
        apps
    }

    /// Aggregate `shard_info` over the composed database.
    pub fn aggregate_info(&self) -> ShardInfoBody {
        let mut configs: Vec<String> = self
            .shards
            .iter()
            .flat_map(|s| s.configs.iter().cloned())
            .collect();
        configs.sort();
        configs.dedup();
        ShardInfoBody {
            entries: self.total_entries(),
            apps: self.apps(),
            configs,
            sessions: Vec::new(),
        }
    }

    /// Shard positions that own `label` (usually exactly one under
    /// `--shard-of` partitioning; all claimants are consulted so overlap
    /// degrades to correct-but-wider fan-out, never to missed entries).
    fn owners(&self, label: &str) -> Vec<usize> {
        self.shards
            .iter()
            .enumerate()
            .filter(|(_, s)| s.configs.iter().any(|c| c == label))
            .map(|(si, _)| si)
            .collect()
    }

    /// Fan one request to `targets` (pipelined: all sends, then all
    /// receives), returning each shard's reply in target order and timing
    /// each round trip into the metrics registry. Each shard gets a child
    /// span of `parent` covering its whole round trip; the span's id is
    /// stamped into the request envelope's `trace` field so the shard's
    /// own request tree nests under it. On any failure, every id still in
    /// flight is [`MrtunerClient::forget`]-gotten so stray replies cannot
    /// accumulate in client buffers across shard flaps.
    fn fan(
        &mut self,
        targets: &[usize],
        req: &Request,
        parent: &Span,
    ) -> Result<Vec<Response>, ClientError> {
        let mut sent: Vec<(usize, u64, u64, Span)> = Vec::with_capacity(targets.len());
        for &si in targets {
            let addr = self.shards[si].addr.clone();
            let span = parent.child("shard");
            span.event("shard", si as u64);
            if span.active() {
                span.note("addr", &addr);
            }
            let t0 = self.tracer.now_ns();
            // The envelope's `trace` field carries the sampling fate, not
            // just the span id: a recording span sends its id (shard tree
            // nests under it), a sampled-out fan-out sends the
            // TRACE_SAMPLED_OUT sentinel (shard records nothing), an
            // untraced router sends 0 (shard applies its own policy). This
            // is what keeps router and shards sampling the *same* requests.
            match self.shards[si].client.send_traced(req, self.tracer.wire_trace(&span)) {
                Ok(id) => sent.push((si, id, t0, span)),
                Err(e) => {
                    for (sj, idj, _, _) in &sent {
                        self.shards[*sj].client.forget(*idj);
                    }
                    return Err(shard_err(&addr, e));
                }
            }
        }
        let mut replies = Vec::with_capacity(sent.len());
        let mut failed: Option<ClientError> = None;
        for (si, id, t0, span) in sent {
            if failed.is_some() {
                self.shards[si].client.forget(id);
                continue;
            }
            let addr = self.shards[si].addr.clone();
            match self.shards[si].client.recv(id) {
                Ok(resp) => {
                    self.metrics
                        .record_shard_fanout(si, self.tracer.elapsed_secs(t0));
                    replies.push(resp);
                }
                // Shards drop connections idle past their CONN_IDLE; the
                // dead socket usually swallows the write and only recv
                // notices. Every routed request is idempotent (streams are
                // not routed), so replay once on a fresh connection before
                // declaring the shard unavailable.
                Err(ClientError::Io(first)) if req.is_idempotent() => {
                    self.shards[si].client.forget(id);
                    log::debug!("router: shard {addr} recv failed ({first}); replaying once");
                    span.event("replayed", 1);
                    // Replay under the same sampling fate as the original
                    // send, so a retried request cannot half-appear in the
                    // stitched trace.
                    let wire = self.tracer.wire_trace(&span);
                    let replay = match self.shards[si].client.send_traced(req, wire) {
                        Ok(rid) => self.shards[si].client.recv(rid),
                        Err(e) => Err(e),
                    };
                    match replay {
                        Ok(resp) => {
                            self.metrics
                                .record_shard_fanout(si, self.tracer.elapsed_secs(t0));
                            replies.push(resp);
                        }
                        Err(e) => failed = Some(shard_err(&addr, e)),
                    }
                }
                Err(e) => {
                    self.shards[si].client.forget(id);
                    failed = Some(shard_err(&addr, e));
                }
            }
            // `span` drops here: the per-shard span closes at reply merge.
        }
        match failed {
            Some(e) => Err(e),
            None => Ok(replies),
        }
    }

    /// Merge per-shard k-NN rows for one query: rebase local indices to
    /// global, then keep the k smallest under the engine's deterministic
    /// `(distance, index)` order.
    fn merge_knn(&self, targets: &[usize], per_shard: Vec<&KnnBody>, k: usize) -> KnnBody {
        let mut rows = Vec::new();
        let mut stats = SearchStats::default();
        for (&si, body) in targets.iter().zip(&per_shard) {
            let base = self.shards[si].base;
            for r in &body.neighbors {
                let mut r = r.clone();
                r.index += base;
                rows.push(r);
            }
            stats.merge(&body.stats);
        }
        rows.sort_by(|a, b| {
            (a.distance, a.index)
                .partial_cmp(&(b.distance, b.index))
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        rows.truncate(k);
        KnnBody {
            neighbors: rows,
            stats,
        }
    }

    /// Routed batched k-NN from an already-decoded [`Request::KnnBatch`]
    /// — the front-end's hot path fans the request it parsed without
    /// re-cloning megabyte-scale payloads. Bit-identical to a single-node
    /// `IndexedDb::knn_batch` over the union database. Per-shard round
    /// trips become child spans of `parent` (pass [`Span::none`] when
    /// untraced).
    pub fn route_knn_batch(
        &mut self,
        req: &Request,
        parent: &Span,
    ) -> Result<KnnBatchBody, ClientError> {
        let (nqueries, k, config) = match req {
            Request::KnnBatch { queries, k, config } => (queries.len(), *k, config.as_ref()),
            _ => {
                return Err(ClientError::Wire(
                    "route_knn_batch needs a KnnBatch request".to_string(),
                ))
            }
        };
        let targets: Vec<usize> = match config {
            Some(cfg) => self.owners(&cfg.label()),
            None => (0..self.shards.len()).collect(),
        };
        let bodies: Vec<KnnBatchBody> = if targets.is_empty() {
            Vec::new()
        } else {
            self.fan(&targets, req, parent)?
                .into_iter()
                .map(|resp| match resp {
                    Response::KnnBatch(b) => Ok(b),
                    other => Err(ClientError::Wire(format!(
                        "expected knn_batch reply, got {}",
                        other.type_name()
                    ))),
                })
                .collect::<Result<_, _>>()?
        };
        for (ti, body) in bodies.iter().enumerate() {
            if body.results.len() != nqueries {
                return Err(ClientError::Wire(format!(
                    "shard {} answered {} results for {nqueries} queries",
                    self.shards[targets[ti]].addr,
                    body.results.len(),
                )));
            }
        }
        let mut results = Vec::with_capacity(nqueries);
        let mut merged = SearchStats::default();
        for qi in 0..nqueries {
            let per_shard: Vec<&KnnBody> = bodies.iter().map(|b| &b.results[qi]).collect();
            let row = self.merge_knn(&targets, per_shard, k);
            merged.merge(&row.stats);
            results.push(row);
        }
        Ok(KnnBatchBody {
            results,
            stats: merged,
        })
    }

    /// [`ShardRouter::route_knn_batch`] over owned query slices (builds
    /// the request once; examples/tests entry point).
    pub fn knn_batch(
        &mut self,
        queries: &[Vec<f64>],
        k: usize,
        config: Option<&JobConfig>,
    ) -> Result<KnnBatchBody, ClientError> {
        let req = Request::KnnBatch {
            queries: queries.to_vec(),
            k,
            config: config.copied(),
        };
        self.route_knn_batch(&req, &Span::none())
    }

    /// Routed single-query k-NN (a batch of one; the series is copied
    /// exactly once, into the request).
    pub fn knn(
        &mut self,
        series: &[f64],
        k: usize,
        config: Option<&JobConfig>,
    ) -> Result<KnnBody, ClientError> {
        let req = Request::KnnBatch {
            queries: vec![series.to_vec()],
            k,
            config: config.copied(),
        };
        let mut batch = self.route_knn_batch(&req, &Span::none())?;
        Ok(batch.results.remove(0))
    }

    /// Routed single-query k-NN with fan-out tracing: same single-element
    /// batch as [`ShardRouter::knn`], but per-shard spans nest under
    /// `parent`.
    fn knn_traced(
        &mut self,
        series: &[f64],
        k: usize,
        config: Option<&JobConfig>,
        parent: &Span,
    ) -> Result<KnnBody, ClientError> {
        let req = Request::KnnBatch {
            queries: vec![series.to_vec()],
            k,
            config: config.copied(),
        };
        let mut batch = self.route_knn_batch(&req, parent)?;
        Ok(batch.results.remove(0))
    }

    /// Routed matching phase from an already-decoded [`Request::Match`]:
    /// fan the raw capture to the shards owning the configuration set and
    /// merge their per-app rows in shard order — the same row order a
    /// single node produces over the union database. Per-shard round
    /// trips become child spans of `parent`.
    pub fn route_match(&mut self, req: &Request, parent: &Span) -> Result<MatchBody, ClientError> {
        let config = match req {
            Request::Match { config, .. } => config,
            _ => {
                return Err(ClientError::Wire(
                    "route_match needs a Match request".to_string(),
                ))
            }
        };
        let targets = self.owners(&config.label());
        if targets.is_empty() {
            return Ok(MatchBody {
                results: Vec::new(),
                matched: None,
                best_similarity: 0.0,
            });
        }
        let mut results = Vec::new();
        for resp in self.fan(&targets, req, parent)? {
            match resp {
                Response::Match(b) => results.extend(b.results),
                other => {
                    return Err(ClientError::Wire(format!(
                        "expected match reply, got {}",
                        other.type_name()
                    )))
                }
            }
        }
        // Recompute the winner over the merged rows with the single-node
        // rule: first row wins ties, strict improvement replaces.
        let mut best: Option<(usize, f64)> = None;
        for (i, r) in results.iter().enumerate() {
            if best.map_or(true, |(_, bs)| r.similarity > bs) {
                best = Some((i, r.similarity));
            }
        }
        let (matched, best_similarity) = match best {
            Some((i, s)) if s >= MATCH_THRESHOLD => (Some(results[i].app.clone()), s),
            Some((_, s)) => (None, s),
            None => (None, 0.0),
        };
        Ok(MatchBody {
            results,
            matched,
            best_similarity,
        })
    }

    /// [`ShardRouter::route_match`] over an owned capture (builds the
    /// request once; examples/tests entry point).
    pub fn match_config(
        &mut self,
        series: &[f64],
        config: &JobConfig,
    ) -> Result<MatchBody, ClientError> {
        let req = Request::Match {
            series: series.to_vec(),
            config: *config,
        };
        self.route_match(&req, &Span::none())
    }
}

/// Dispatch one routed request. Stream commands are rejected: sessions
/// live on the shard owning their configuration set.
pub fn dispatch_routed(
    req: &Request,
    router: &Mutex<ShardRouter>,
) -> Result<Response, ServerError> {
    dispatch_routed_traced(req, router, &Span::none())
}

/// [`dispatch_routed`] with fan-out tracing: per-command spans (and the
/// per-shard round-trip spans under them) nest under `parent`.
pub fn dispatch_routed_traced(
    req: &Request,
    router: &Mutex<ShardRouter>,
    parent: &Span,
) -> Result<Response, ServerError> {
    let to_server = |e: ClientError| match e {
        ClientError::Server(se) => se,
        other => ServerError::new(ErrorCode::ShardUnavailable, other.to_string()),
    };
    // A panic while the lock was held (a bug elsewhere) poisons it; report
    // that as a typed Internal error rather than cascading the panic into
    // every later connection.
    let mut r = match router.lock() {
        Ok(guard) => guard,
        Err(_) => return Err(ServerError::new(ErrorCode::Internal, "router lock poisoned")),
    };
    match req {
        Request::Ping => Ok(Response::Pong),
        Request::Apps => Ok(Response::Apps(r.apps())),
        Request::ShardInfo => Ok(Response::ShardInfo(r.aggregate_info())),
        Request::Stats => Ok(Response::Stats(StatsBody {
            report: r.metrics().report(),
            db_entries: r.total_entries(),
            live_sessions: 0,
        })),
        Request::Metrics => Ok(Response::Metrics(r.metrics().snapshot())),
        Request::Knn { series, k, config } => {
            let span = parent.child("knn");
            span.event("k", *k as u64);
            r.knn_traced(series, *k, config.as_ref(), &span)
                .map(Response::Knn)
                .map_err(to_server)
        }
        // Fan the decoded request itself — no payload re-clone on the
        // router's hot path.
        Request::KnnBatch { queries, .. } => {
            let span = parent.child("knn_batch");
            span.event("queries", queries.len() as u64);
            r.route_knn_batch(req, &span)
                .map(Response::KnnBatch)
                .map_err(to_server)
        }
        Request::Match { .. } => {
            let span = parent.child("match");
            r.route_match(req, &span)
                .map(Response::Match)
                .map_err(to_server)
        }
        Request::StreamOpen { .. }
        | Request::StreamFeed { .. }
        | Request::StreamPoll { .. }
        | Request::StreamPollAll { .. }
        | Request::StreamClose { .. } => Err(ServerError::bad_request(
            "stream sessions are not routed; open them against the shard owning the config set",
        )),
        // Each flight recorder is process-local forensics; a merged dump
        // would scramble span ids across processes. Ask each shard.
        Request::TraceDump => Err(ServerError::bad_request(
            "trace_dump is not routed; ask each shard directly",
        )),
    }
}

/// Decode, route and render one request line against the router —
/// the router-side sibling of `server::handle_line` (same envelopes, same
/// error accounting, same `decode` / `handle` / `encode` span taxonomy).
pub fn route_line(
    line: &str,
    router: &Mutex<ShardRouter>,
    metrics: &Metrics,
    tracer: &TraceHandle,
) -> Json {
    let t0 = tracer.timestamp();
    let (wire, decoded) = decode_line(line);
    let t1 = tracer.timestamp();
    let (remote, key) = match wire {
        Wire::V2 { trace, id } => (trace, id),
        Wire::V1 => (0, 0),
    };
    // Same sampling protocol as `server::handle_line`: the decision made
    // here rides every fan-out envelope (see `ShardRouter::fan`), so the
    // router and its shards keep or drop the same requests.
    let root = tracer.root_sampled("request", remote, key);
    if tracer.enabled() {
        if root.active() {
            metrics.inc_spans_recorded();
            tracer.span_at("decode", root.id(), t0, t1);
        } else {
            metrics.inc_spans_sampled_out();
        }
    }
    let result = {
        let handle = root.child("handle");
        decoded.and_then(|req| {
            handle.note("type", req.type_name());
            dispatch_routed_traced(&req, router, &handle)
        })
    };
    if let Err(e) = &result {
        metrics.inc_errors();
        metrics.inc_proto_error(e.code);
        root.note("error", e.code.as_str());
    }
    let encode = root.child("encode");
    let reply = encode_reply(&wire, &result);
    drop(encode);
    reply
}

/// The routing front-end: a TCP server speaking the same line protocol as
/// the shards (both envelopes), forwarding searches through a
/// [`ShardRouter`].
pub struct RouterServer {
    listener: TcpListener,
    router: Arc<Mutex<ShardRouter>>,
    metrics: Arc<Metrics>,
    /// The router's trace handle, cloned out before the router moves into
    /// its lock so connection loops can time and span without locking.
    tracer: TraceHandle,
    stop: Arc<AtomicBool>,
}

impl RouterServer {
    /// Bind to `addr` (port 0 for ephemeral). The router's own metrics
    /// registry doubles as the server's, and its tracer (if any —
    /// [`ShardRouter::with_tracer`]) spans every front-end request.
    pub fn bind(addr: &str, router: ShardRouter) -> Result<RouterServer> {
        let metrics = Arc::clone(router.metrics());
        let tracer = router.tracer.clone();
        let listener = TcpListener::bind(addr)?;
        Ok(RouterServer {
            listener,
            router: Arc::new(Mutex::new(router)),
            metrics,
            tracer,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Stop handle: set true and connect once to unblock accept().
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Serve until the stop flag is raised (default read timeout).
    pub fn serve(&self, workers: usize) -> Result<()> {
        self.serve_with(workers, READ_TIMEOUT)
    }

    /// Serve until the stop flag is raised. Connections are accepted on a
    /// pool; routed dispatch serializes on the router lock (each routed
    /// search already fans across every shard, so cross-request
    /// parallelism would only thrash the shards).
    pub fn serve_with(&self, workers: usize, read_timeout: Duration) -> Result<()> {
        let pool = ThreadPool::new(workers.max(1));
        log::info!("routing on {}", self.listener.local_addr()?);
        for conn in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let router = Arc::clone(&self.router);
                    let metrics = Arc::clone(&self.metrics);
                    let tracer = self.tracer.clone();
                    let stop = Arc::clone(&self.stop);
                    pool.execute(move || {
                        if let Err(e) = route_connection(
                            stream,
                            &router,
                            &metrics,
                            &tracer,
                            &stop,
                            read_timeout,
                        ) {
                            log::debug!("router connection ended: {e:#}");
                        }
                    });
                }
                Err(e) => log::warn!("router accept failed: {e}"),
            }
        }
        Ok(())
    }
}

fn route_connection(
    stream: TcpStream,
    router: &Mutex<ShardRouter>,
    metrics: &Metrics,
    tracer: &TraceHandle,
    stop: &AtomicBool,
    read_timeout: Duration,
) -> Result<()> {
    // Same hardened read loop as the match server (bounded line framing,
    // idle ticks, structured rejects); the router has no sessions to reap.
    serve_connection_lines(
        stream,
        metrics,
        tracer,
        stop,
        read_timeout,
        || (),
        |line| route_line(line, router, metrics, tracer),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routed_stream_commands_are_rejected() {
        // A router with zero shards still dispatches local commands.
        let router = Mutex::new(ShardRouter {
            shards: Vec::new(),
            metrics: Arc::new(Metrics::new()),
            tracer: TraceHandle::disabled(),
        });
        let err = dispatch_routed(&Request::StreamPollAll { k: 3 }, &router).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("not routed"), "{}", err.message);
        // Local aggregates answer without any shard traffic.
        match dispatch_routed(&Request::ShardInfo, &router).unwrap() {
            Response::ShardInfo(info) => {
                assert_eq!(info.entries, 0);
                assert!(info.apps.is_empty());
            }
            other => panic!("{other:?}"),
        }
        match dispatch_routed(&Request::Ping, &router).unwrap() {
            Response::Pong => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn merge_is_deterministic_on_ties() {
        use crate::protocol::NeighborRow;
        let router = ShardRouter {
            shards: vec![
                Shard {
                    addr: "a".into(),
                    base: 0,
                    entries: 2,
                    apps: vec![],
                    configs: vec![],
                    client: unconnected_client(),
                },
                Shard {
                    addr: "b".into(),
                    base: 2,
                    entries: 2,
                    apps: vec![],
                    configs: vec![],
                    client: unconnected_client(),
                },
            ],
            metrics: Arc::new(Metrics::new()),
            tracer: TraceHandle::disabled(),
        };
        let row = |index: usize, distance: f64| NeighborRow {
            index,
            app: "wordcount".into(),
            config: "c".into(),
            distance,
            similarity: 0.0,
        };
        // Shard b holds an equal-distance row; global tie must resolve to
        // the lower global index (shard a's entry 1 = global 1, before
        // shard b's entry 0 = global 2).
        let a = KnnBody {
            neighbors: vec![row(0, 0.5), row(1, 1.0)],
            stats: SearchStats::default(),
        };
        let b = KnnBody {
            neighbors: vec![row(0, 1.0), row(1, 2.0)],
            stats: SearchStats::default(),
        };
        let merged = router.merge_knn(&[0, 1], vec![&a, &b], 3);
        let got: Vec<(usize, f64)> = merged.neighbors.iter().map(|r| (r.index, r.distance)).collect();
        assert_eq!(got, vec![(0, 0.5), (1, 1.0), (2, 1.0)]);
    }

    /// A client that never connected (test-only: merge logic needs a
    /// `Shard` but never touches the socket).
    fn unconnected_client() -> MrtunerClient {
        // Port 1 on localhost is essentially never listening; but to keep
        // the test hermetic we do not even try: construct via connect to a
        // listener we immediately satisfy.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let _ = listener.accept();
        });
        let client = MrtunerClient::connect(&addr.to_string()).unwrap();
        t.join().unwrap();
        client
    }
}
