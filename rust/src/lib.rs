//! # mrtuner — pattern-matching self-tuning for MapReduce jobs
//!
//! Reproduction of *"Pattern Matching for Self-Tuning of MapReduce Jobs"*
//! (Rizvandi, Taheri, Zomaya — IEEE ISPA 2011, DOI 10.1109/ISPA.2011.24)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (build-time Python): the DTW dynamic program and the 6th-order
//!   Chebyshev de-noising filter as Pallas kernels, AOT-lowered to HLO text.
//! * **L2** (build-time Python): the matching pipeline (preprocess →
//!   DTW → traceback inputs) as jitted JAX entry points, one per shape bucket.
//! * **L3** (this crate): the paper's system — a pseudo-distributed MapReduce
//!   simulator substrate, workload implementations, the profiling phase, the
//!   matching phase (DTW + correlation vote), and the self-tuner that
//!   transfers optimal configurations between matched applications.
//!
//! Python never runs on the request path: `make artifacts` lowers the HLO
//! once, and [`runtime`] loads and executes it through the PJRT C API
//! (`xla` crate, behind the `pjrt` cargo feature). Every runtime
//! computation also has a bit-compatible pure Rust fallback ([`signal`],
//! [`dtw`]) used when artifacts are absent and to cross-check the compiled
//! path in tests.
//!
//! On top of the paper's brute-force matching phase sits the [`index`]
//! layer: a lower-bound-cascade similarity index
//! (LB_Kim → PAA envelope → LB_Keogh → early-abandoning banded DTW) that
//! makes k-nearest-neighbour retrieval sublinear in reference-database
//! size while returning exactly the brute-force neighbours. The
//! coordinator exposes it as
//! [`coordinator::matcher::Matcher::match_app_indexed`] and the serve
//! loop's `knn` command; pruning effectiveness is tracked in
//! [`coordinator::metrics::Metrics`] and measured by
//! `benches/index_perf.rs`.
//!
//! The [`streaming`] layer turns that index into an *online* classifier:
//! a [`streaming::StreamSession`] ingests a live CPU capture batch by
//! batch, maintains monotone prefix lower bounds over the index's
//! envelope cache, and declares an anytime decision before the job
//! finishes ([`coordinator::matcher::Matcher::match_stream`], the serve
//! loop's `stream_*` commands, `benches/stream_perf.rs`).
//!
//! The service boundary is typed: [`protocol`] defines the full wire
//! surface (versioned v2 envelope with per-request ids, `Request` /
//! `Response` enums, `ErrorCode`s) with a byte-compatible v1 shim;
//! [`client::MrtunerClient`] is the reconnecting, pipelining client; and
//! [`coordinator::router::ShardRouter`] composes per-config shard servers
//! into one logical database whose routed k-NN answers are bit-identical
//! to a single node over the union (see `PROTOCOL.md`).
//!
//! The [`tuning`] layer closes the control loop the paper leaves open:
//! a [`tuning::LengthPredictor`] refines the streaming classifier's
//! final-length geometry from live task progress, and
//! [`tuning::run_tuned`] reconfigures a *running* simulated job to the
//! matched application's cached optimal mid-run, behind a
//! [`tuning::TuningController`] hysteresis gate so flapping matches
//! cannot thrash the job (`benches/tuning_ab.rs` measures the payoff).
//!
//! Observability is cross-cutting: [`trace`] provides per-request span
//! trees with pluggable sinks (null / in-memory / text / Chrome
//! `trace_event` JSON), threaded through server dispatch, router fan-out,
//! the cascade and streaming sessions, with trace identity propagated
//! across the wire via the v2 envelope's optional `trace` field (see
//! `OBSERVABILITY.md`).

pub mod client;
pub mod coordinator;
pub mod database;
pub mod dtw;
pub mod faultproxy;
pub mod index;
pub mod protocol;
pub mod runtime;
pub mod signal;
pub mod simulator;
pub mod streaming;
pub mod trace;
pub mod tuning;
pub mod util;
pub mod workloads;

/// Convenient re-exports covering the public API surface used by the
/// examples and the CLI.
pub mod prelude {
    pub use crate::client::MrtunerClient;
    pub use crate::coordinator::router::{RouterServer, ShardRouter};
    pub use crate::coordinator::{
        matcher::{MatchOutcome, Matcher},
        profiler::Profiler,
        tuner::{Tuner, TuningReport},
        ConfigGrid, SystemConfig, TuningSystem,
    };
    pub use crate::database::{profile::ProfileEntry, store::ReferenceDb};
    pub use crate::dtw::{corr::similarity_percent, full::DtwResult};
    pub use crate::index::{IndexedDb, Neighbor, SearchStats};
    pub use crate::protocol::{ErrorCode, Request, Response};
    pub use crate::simulator::job::JobConfig;
    pub use crate::streaming::{
        DecisionPolicy, FinalLen, SessionManager, StreamDecision, StreamSession,
    };
    pub use crate::trace::{
        ChromeTracker, InMemoryTracker, NullTracker, Span, TextTracker, TraceHandle,
    };
    pub use crate::tuning::{run_tuned, ControllerPolicy, LengthPredictor, TuningController};
    pub use crate::workloads::AppId;
}
