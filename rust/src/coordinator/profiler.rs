//! Profiling phase (paper §4, Figure 4a): run an application under every
//! configuration set, capture its 1 Hz CPU series, de-noise + normalize it
//! and emit database entries.

use super::{ConfigGrid, SystemConfig};
use crate::database::profile::ProfileEntry;
use crate::runtime::{Padded, RuntimeHandle};
use crate::simulator::{engine::simulate, job::JobConfig};
use crate::trace::TraceHandle;
use crate::util::pool::par_map;
use crate::util::rng::Rng;
use crate::workloads::{workload_for, AppId};

/// Runs the profiling phase.
pub struct Profiler {
    config: SystemConfig,
    runtime: Option<RuntimeHandle>,
    /// Span sink for grid runs; disabled by default
    /// ([`Profiler::with_tracer`] to attach one).
    tracer: TraceHandle,
}

impl Profiler {
    pub fn new(config: &SystemConfig, runtime: Option<RuntimeHandle>) -> Profiler {
        Profiler {
            config: config.clone(),
            runtime,
            tracer: TraceHandle::disabled(),
        }
    }

    /// Attach a tracer (builder-style): each [`Profiler::profile`] call
    /// becomes a root `profile` span carrying the app and grid size.
    pub fn with_tracer(mut self, tracer: TraceHandle) -> Profiler {
        self.tracer = tracer;
        self
    }

    /// Deterministic per-(app, config) seed so re-profiling one set does
    /// not disturb the others.
    fn run_seed(&self, app: AppId, cfg: &JobConfig) -> u64 {
        let mut h: u64 = self.config.seed ^ 0x9e37_79b9_0000_0000;
        for b in app.name().bytes().chain(cfg.label().bytes()) {
            h = h.wrapping_mul(0x100_0000_01b3) ^ b as u64;
        }
        h
    }

    /// Profile one application over the whole grid (parallel).
    pub fn profile(&self, app: AppId, grid: &ConfigGrid) -> Vec<ProfileEntry> {
        let span = self.tracer.root("profile");
        if span.active() {
            span.note("app", app.name());
        }
        span.event("configs", grid.len() as u64);
        par_map(&grid.configs, self.config.workers, |cfg| {
            self.profile_one(app, cfg)
        })
    }

    /// One run: simulate → capture noisy series → de-noise + normalize.
    pub fn profile_one(&self, app: AppId, cfg: &JobConfig) -> ProfileEntry {
        let workload = workload_for(app);
        let mut rng = Rng::new(self.run_seed(app, cfg));
        let result = simulate(
            workload.as_ref(),
            cfg,
            &self.config.cluster,
            &self.config.noise,
            &mut rng,
        );
        let raw_len = result.cpu_noisy.len();
        let series = self.preprocess(&result.cpu_noisy);
        ProfileEntry {
            app,
            config: *cfg,
            series,
            raw_len,
            completion_secs: result.completion_secs,
        }
    }

    /// De-noise + normalize a raw capture — PJRT path when available,
    /// bit-compatible Rust fallback otherwise.
    pub fn preprocess(&self, raw: &[f64]) -> Vec<f64> {
        if let Some(rt) = &self.runtime {
            let bucket = rt.bucket_for(raw.len());
            let padded = Padded::fit(raw, bucket);
            match rt.preprocess(padded) {
                Ok(out) => return out.valid(),
                Err(e) => log::warn!("runtime preprocess failed ({e:#}); falling back"),
            }
        }
        let capped = if raw.len() > 512 {
            crate::signal::resample::linear(raw, 512)
        } else {
            raw.to_vec()
        };
        crate::signal::preprocess(&capped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profiler() -> Profiler {
        let config = SystemConfig {
            workers: 2,
            use_runtime: false,
            ..SystemConfig::default()
        };
        Profiler::new(&config, None)
    }

    #[test]
    fn profiles_are_deterministic() {
        let p = profiler();
        let cfg = JobConfig::new(4, 2, 10.0, 20.0);
        let a = p.profile_one(AppId::WordCount, &cfg);
        let b = p.profile_one(AppId::WordCount, &cfg);
        assert_eq!(a, b);
    }

    #[test]
    fn series_normalized_to_unit_range() {
        let p = profiler();
        let e = p.profile_one(AppId::TeraSort, &JobConfig::new(4, 2, 10.0, 30.0));
        assert!(!e.series.is_empty());
        for &v in &e.series {
            assert!((0.0..=1.0).contains(&v), "v={v}");
        }
        // min-max normalization touches both bounds
        let max = e.series.iter().cloned().fold(0.0, f64::max);
        assert!((max - 1.0).abs() < 1e-9);
    }

    #[test]
    fn grid_profile_covers_all_configs() {
        let p = profiler();
        let grid = ConfigGrid::small(3);
        let entries = p.profile(AppId::Grep, &grid);
        assert_eq!(entries.len(), grid.len());
        let mut keys: Vec<String> = entries.iter().map(|e| e.config_key()).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), grid.len(), "duplicate config keys");
    }

    #[test]
    fn long_series_resampled_to_bucket() {
        let p = profiler();
        // 500 MB of WordCount takes far longer than 512 s.
        let e = p.profile_one(AppId::WordCount, &JobConfig::new(8, 4, 50.0, 400.0));
        assert!(e.raw_len > 512);
        assert_eq!(e.series.len(), 512);
    }

    #[test]
    fn different_apps_produce_different_series() {
        let p = profiler();
        let cfg = JobConfig::new(6, 3, 10.0, 40.0);
        let wc = p.profile_one(AppId::WordCount, &cfg);
        let ts = p.profile_one(AppId::TeraSort, &cfg);
        assert_ne!(wc.series, ts.series);
    }
}
