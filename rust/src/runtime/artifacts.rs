//! Artifact manifest: what `python/compile/aot.py` produced.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Kind of compiled entry point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EntryKind {
    Preprocess,
    DtwPair,
    DtwBatch,
    MatchOne,
}

impl EntryKind {
    fn parse(s: &str) -> Option<EntryKind> {
        match s {
            "preprocess" => Some(EntryKind::Preprocess),
            "dtw_pair" => Some(EntryKind::DtwPair),
            "dtw_batch" => Some(EntryKind::DtwBatch),
            "match_one" => Some(EntryKind::MatchOne),
            _ => None,
        }
    }
}

/// One compiled artifact.
#[derive(Debug, Clone)]
pub struct EntryMeta {
    pub name: String,
    pub file: String,
    pub kind: EntryKind,
    /// Shape bucket (series length L).
    pub len: usize,
    /// Batch size for batched kinds.
    pub batch: usize,
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub batch: usize,
    pub buckets: Vec<usize>,
    pub entries: Vec<EntryMeta>,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let json = Json::parse(&text).context("parsing manifest.json")?;
        let batch = json
            .get("batch")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing batch"))?;
        let mut buckets = json
            .get("buckets")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing buckets"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect::<Vec<_>>();
        buckets.sort_unstable();
        let mut entries = Vec::new();
        for e in json
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing entries"))?
        {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("entry missing name"))?
                .to_string();
            let kind = e
                .get("kind")
                .and_then(Json::as_str)
                .and_then(EntryKind::parse)
                .ok_or_else(|| anyhow!("entry {name}: bad kind"))?;
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("entry {name}: missing file"))?
                .to_string();
            let len = e
                .get("len")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("entry {name}: missing len"))?;
            let batch = e.get("batch").and_then(Json::as_usize).unwrap_or(1);
            if !dir.join(&file).exists() {
                return Err(anyhow!("artifact file {file} missing from {}", dir.display()));
            }
            entries.push(EntryMeta {
                name,
                file,
                kind,
                len,
                batch,
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            batch,
            buckets,
            entries,
        })
    }

    /// Default artifact directory: `$MRTUNER_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("MRTUNER_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Smallest bucket that fits a series of `len` samples, if any.
    pub fn bucket_for(&self, len: usize) -> Option<usize> {
        self.buckets.iter().copied().find(|&b| b >= len)
    }

    /// Largest available bucket (series longer than this get resampled).
    pub fn max_bucket(&self) -> usize {
        self.buckets.last().copied().unwrap_or(0)
    }

    /// Find a specific entry.
    pub fn entry(&self, kind: EntryKind, len: usize) -> Option<&EntryMeta> {
        self.entries.iter().find(|e| e.kind == kind && e.len == len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("preprocess_128.hlo.txt"), "HloModule fake").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"batch": 8, "buckets": [128], "entries": [
                {"name": "preprocess_128", "file": "preprocess_128.hlo.txt",
                 "kind": "preprocess", "len": 128,
                 "inputs": [], "sha256": "x"}
            ]}"#,
        )
        .unwrap();
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join("mrtuner_manifest_test");
        write_fake(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.batch, 8);
        assert_eq!(m.buckets, vec![128]);
        assert_eq!(m.entries.len(), 1);
        assert_eq!(m.entries[0].kind, EntryKind::Preprocess);
        assert!(m.entry(EntryKind::Preprocess, 128).is_some());
        assert!(m.entry(EntryKind::DtwBatch, 128).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bucket_selection() {
        let dir = std::env::temp_dir().join("mrtuner_manifest_test2");
        write_fake(&dir);
        let mut m = Manifest::load(&dir).unwrap();
        m.buckets = vec![128, 256, 512];
        assert_eq!(m.bucket_for(100), Some(128));
        assert_eq!(m.bucket_for(128), Some(128));
        assert_eq!(m.bucket_for(300), Some(512));
        assert_eq!(m.bucket_for(513), None);
        assert_eq!(m.max_bucket(), 512);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_rejected() {
        let dir = std::env::temp_dir().join("mrtuner_manifest_test3");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"batch": 8, "buckets": [128], "entries": [
                {"name": "x", "file": "nope.hlo.txt", "kind": "preprocess", "len": 128}
            ]}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
