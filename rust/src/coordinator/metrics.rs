//! Service metrics: counters and latency statistics for the serve loop and
//! the perf benches.

use crate::index::SearchStats;
use crate::protocol::ErrorCode;
use crate::streaming::StreamStats;
use crate::util::json::Json;
use crate::util::stats::{LogHistogram, Welford};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// A [`Welford`] accumulator paired with a [`LogHistogram`]: exact
/// mean/min/max plus factor-2-resolution p50/p95/p99, still O(1) memory.
#[derive(Debug, Default)]
struct LatencyTrack {
    w: Welford,
    h: LogHistogram,
}

impl LatencyTrack {
    fn push(&mut self, secs: f64) {
        self.w.push(secs);
        self.h.record(secs);
    }
}

/// Thread-safe metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    pub comparisons: AtomicU64,
    pub batches: AtomicU64,
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    /// Jobs that panicked inside the serve pool and were caught by a
    /// worker (wired via [`crate::util::pool::PanicHook`]). Nonzero means
    /// a handler bug: the pool survived, but the connection died mid-line.
    pub pool_panics: AtomicU64,
    /// Index-search counters (see [`SearchStats`]): candidates examined and
    /// where the cascade culled them. `index_dtw_evals / index_candidates`
    /// is the live "DTW evaluations not avoided" ratio.
    pub index_candidates: AtomicU64,
    pub index_pruned_lb_kim: AtomicU64,
    pub index_pruned_lb_paa: AtomicU64,
    pub index_pruned_lb_keogh: AtomicU64,
    pub index_abandoned: AtomicU64,
    pub index_dtw_evals: AtomicU64,
    /// Streaming-session counters: lifecycle, per-session work folded in
    /// at close/reap time, and early decisions.
    pub stream_opened: AtomicU64,
    pub stream_closed: AtomicU64,
    pub stream_reaped: AtomicU64,
    pub stream_batches: AtomicU64,
    pub stream_culled: AtomicU64,
    pub stream_decisions: AtomicU64,
    /// Batched k-NN counters: how many `knn_batch` requests ran and how
    /// many queries they carried (queries / batches = realized batch
    /// size — the envelope-pass sharing factor).
    pub knn_batches: AtomicU64,
    pub knn_batch_queries: AtomicU64,
    /// Trace-layer counters: roots recorded vs. sampled out by the serve
    /// paths, plus the flight recorder's eviction/dump gauges (synced
    /// from the recorder when a snapshot is served — the recorder counts
    /// for itself, monotonically).
    pub spans_recorded: AtomicU64,
    pub spans_sampled_out: AtomicU64,
    pub recorder_dropped: AtomicU64,
    pub recorder_dumps: AtomicU64,
    /// Fault-tolerance counters recorded by the shard router: in-place
    /// replays after an I/O failure, replica switches, circuit-breaker
    /// trips, admitted half-open probes, and shard slots a partial reply
    /// was served without.
    pub shard_retries: AtomicU64,
    pub shard_failovers: AtomicU64,
    pub circuit_opens: AtomicU64,
    pub circuit_probes: AtomicU64,
    pub degraded_shards: AtomicU64,
    /// Self-tuning counters: predictor observations folded in from
    /// `stream_feed` progress reports, final-length hints actually applied
    /// to a session (split by [`crate::streaming::FinalLen`] variant),
    /// `stream_tune` recommendations served, and — for embedded
    /// controllers reporting back — live reconfigurations applied and
    /// flapping votes the hysteresis gate suppressed.
    pub tuning_predictor_updates: AtomicU64,
    pub tuning_hints_known: AtomicU64,
    pub tuning_hints_at_most: AtomicU64,
    pub tuning_tunes_served: AtomicU64,
    pub tuning_reconfigs: AtomicU64,
    pub tuning_suppressed_flaps: AtomicU64,
    /// Wall-clock of each whole batch (not per query).
    knn_batch_latency: Mutex<LatencyTrack>,
    latency: Mutex<LatencyTrack>,
    /// Protocol rejects by [`ErrorCode`] (indexed by `ErrorCode::index`):
    /// malformed lines, unknown commands/sessions, wrong versions, ... —
    /// the serve loop counts every structured error response here.
    proto_errors: [AtomicU64; ErrorCode::ALL.len()],
    /// Per-shard fan-out latency (send → merged reply) recorded by the
    /// router, keyed by shard position.
    shard_fanout: Mutex<BTreeMap<usize, LatencyTrack>>,
    /// Prefix fraction observed when a session declared its decision —
    /// the streaming classifier's headline "how early" number.
    decision_fraction: Mutex<Welford>,
    /// Samples observed at decision time (decision latency in samples;
    /// at the 1 Hz SysStat rate this is seconds of job runtime).
    decision_samples: Mutex<Welford>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn inc_comparisons(&self, n: u64) {
        self.comparisons.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc_batches(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_requests(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_errors(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_pool_panics(&self) {
        self.pool_panics.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold one index search's pruning counters into the registry.
    pub fn record_search(&self, s: &SearchStats) {
        self.index_candidates.fetch_add(s.candidates, Ordering::Relaxed);
        self.index_pruned_lb_kim
            .fetch_add(s.pruned_lb_kim, Ordering::Relaxed);
        self.index_pruned_lb_paa
            .fetch_add(s.pruned_lb_paa, Ordering::Relaxed);
        self.index_pruned_lb_keogh
            .fetch_add(s.pruned_lb_keogh, Ordering::Relaxed);
        self.index_abandoned.fetch_add(s.abandoned, Ordering::Relaxed);
        self.index_dtw_evals.fetch_add(s.dtw_evals, Ordering::Relaxed);
    }

    /// Snapshot of the accumulated index counters.
    pub fn search_stats(&self) -> SearchStats {
        SearchStats {
            candidates: self.index_candidates.load(Ordering::Relaxed),
            pruned_lb_kim: self.index_pruned_lb_kim.load(Ordering::Relaxed),
            pruned_lb_paa: self.index_pruned_lb_paa.load(Ordering::Relaxed),
            pruned_lb_keogh: self.index_pruned_lb_keogh.load(Ordering::Relaxed),
            abandoned: self.index_abandoned.load(Ordering::Relaxed),
            dtw_evals: self.index_dtw_evals.load(Ordering::Relaxed),
        }
    }

    pub fn inc_stream_opened(&self) {
        self.stream_opened.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_stream_closed(&self) {
        self.stream_closed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_stream_reaped(&self, n: u64) {
        self.stream_reaped.fetch_add(n, Ordering::Relaxed);
    }

    /// Fold one finished session's work counters into the registry.
    pub fn record_stream_session(&self, s: &StreamStats) {
        self.stream_batches.fetch_add(s.batches, Ordering::Relaxed);
        self.stream_culled.fetch_add(s.culled, Ordering::Relaxed);
    }

    /// Fold one batched k-NN request into the registry: how many queries
    /// it carried and the whole batch's wall-clock.
    pub fn record_knn_batch(&self, queries: u64, seconds: f64) {
        self.knn_batches.fetch_add(1, Ordering::Relaxed);
        self.knn_batch_queries.fetch_add(queries, Ordering::Relaxed);
        self.knn_batch_latency
            .lock()
            .expect("batch latency lock")
            .push(seconds);
    }

    /// Snapshot: (batches, queries, mean batch latency in seconds).
    pub fn knn_batch_summary(&self) -> (u64, u64, f64) {
        let t = self.knn_batch_latency.lock().expect("batch latency lock");
        (
            self.knn_batches.load(Ordering::Relaxed),
            self.knn_batch_queries.load(Ordering::Relaxed),
            t.w.mean(),
        )
    }

    /// Batch-latency quantiles: (p50_s, p95_s, p99_s).
    pub fn knn_batch_quantiles(&self) -> (f64, f64, f64) {
        let t = self.knn_batch_latency.lock().expect("batch latency lock");
        (t.h.quantile(0.50), t.h.quantile(0.95), t.h.quantile(0.99))
    }

    /// Record an early decision: at which sample and prefix fraction it
    /// was declared.
    pub fn record_stream_decision(&self, at_sample: usize, fraction: f64) {
        self.stream_decisions.fetch_add(1, Ordering::Relaxed);
        self.decision_samples
            .lock()
            .expect("decision samples lock")
            .push(at_sample as f64);
        self.decision_fraction
            .lock()
            .expect("decision fraction lock")
            .push(fraction);
    }

    /// Snapshot: (decisions, mean samples at decision, mean fraction).
    pub fn decision_summary(&self) -> (u64, f64, f64) {
        let s = self.decision_samples.lock().expect("decision samples lock");
        let f = self.decision_fraction.lock().expect("decision fraction lock");
        (s.count(), s.mean(), f.mean())
    }

    /// Count one request root span actually recorded by the tracer.
    pub fn inc_spans_recorded(&self) {
        self.spans_recorded.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one request root the sampling policy (local or upstream)
    /// dropped while tracing was otherwise on.
    pub fn inc_spans_sampled_out(&self) {
        self.spans_sampled_out.fetch_add(1, Ordering::Relaxed);
    }

    /// Sync the flight recorder's own monotone counters into the
    /// registry (called when a snapshot is about to be served).
    pub fn set_recorder_stats(&self, dropped: u64, dumps: u64) {
        self.recorder_dropped.store(dropped, Ordering::Relaxed);
        self.recorder_dumps.store(dumps, Ordering::Relaxed);
    }

    /// Snapshot: (spans_recorded, spans_sampled_out, recorder_dropped,
    /// recorder_dumps).
    pub fn trace_summary(&self) -> (u64, u64, u64, u64) {
        (
            self.spans_recorded.load(Ordering::Relaxed),
            self.spans_sampled_out.load(Ordering::Relaxed),
            self.recorder_dropped.load(Ordering::Relaxed),
            self.recorder_dumps.load(Ordering::Relaxed),
        )
    }

    /// Count one protocol reject under its error code.
    pub fn inc_proto_error(&self, code: ErrorCode) {
        self.proto_errors[code.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// Rejects recorded under one code.
    pub fn proto_error_count(&self, code: ErrorCode) -> u64 {
        self.proto_errors[code.index()].load(Ordering::Relaxed)
    }

    /// Rejects across every code.
    pub fn proto_errors_total(&self) -> u64 {
        self.proto_errors
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .sum()
    }

    /// Count one in-place replay of a shard request (same replica, fresh
    /// connection) after an I/O failure.
    pub fn inc_shard_retry(&self) {
        self.shard_retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one replica switch that produced an answer (or reached a
    /// healthy replica that refused with a structured error).
    pub fn inc_shard_failover(&self) {
        self.shard_failovers.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one circuit-breaker trip (closed or half-open → open).
    pub fn inc_circuit_open(&self) {
        self.circuit_opens.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one admitted half-open probe on an open breaker.
    pub fn inc_circuit_probe(&self) {
        self.circuit_probes.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one shard slot a degraded (partial) reply was served
    /// without.
    pub fn inc_degraded_shard(&self) {
        self.degraded_shards.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot: (retries, failovers, circuit_opens, circuit_probes,
    /// degraded_shards).
    pub fn fault_summary(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.shard_retries.load(Ordering::Relaxed),
            self.shard_failovers.load(Ordering::Relaxed),
            self.circuit_opens.load(Ordering::Relaxed),
            self.circuit_probes.load(Ordering::Relaxed),
            self.degraded_shards.load(Ordering::Relaxed),
        )
    }

    /// Count one predictor update folded in from a `stream_feed`
    /// progress report.
    pub fn inc_tuning_predictor_update(&self) {
        self.tuning_predictor_updates.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one `FinalLen::Known` hint applied to a live session.
    pub fn inc_tuning_hint_known(&self) {
        self.tuning_hints_known.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one `FinalLen::AtMost` hint applied to a live session.
    pub fn inc_tuning_hint_at_most(&self) {
        self.tuning_hints_at_most.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one `stream_tune` recommendation served.
    pub fn inc_tuning_tune_served(&self) {
        self.tuning_tunes_served.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one live reconfiguration a controller actually applied.
    pub fn inc_tuning_reconfig(&self) {
        self.tuning_reconfigs.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold in flapping votes a controller's hysteresis gate suppressed.
    pub fn add_tuning_suppressed(&self, n: u64) {
        self.tuning_suppressed_flaps.fetch_add(n, Ordering::Relaxed);
    }

    /// Snapshot: (predictor_updates, hints_known, hints_at_most,
    /// tunes_served, reconfigs, suppressed_flaps).
    pub fn tuning_summary(&self) -> (u64, u64, u64, u64, u64, u64) {
        (
            self.tuning_predictor_updates.load(Ordering::Relaxed),
            self.tuning_hints_known.load(Ordering::Relaxed),
            self.tuning_hints_at_most.load(Ordering::Relaxed),
            self.tuning_tunes_served.load(Ordering::Relaxed),
            self.tuning_reconfigs.load(Ordering::Relaxed),
            self.tuning_suppressed_flaps.load(Ordering::Relaxed),
        )
    }

    /// Record one shard's fan-out round trip (send → reply merged).
    pub fn record_shard_fanout(&self, shard: usize, seconds: f64) {
        self.shard_fanout
            .lock()
            .expect("shard fanout lock")
            .entry(shard)
            .or_default()
            .push(seconds);
    }

    /// Snapshot: per shard `(shard, calls, mean_s, max_s)`, shard-ordered.
    pub fn shard_fanout_summary(&self) -> Vec<(usize, u64, f64, f64)> {
        self.shard_fanout
            .lock()
            .expect("shard fanout lock")
            .iter()
            .map(|(&s, t)| (s, t.w.count(), t.w.mean(), t.w.max()))
            .collect()
    }

    /// Fan-out latency aggregated across *all* shards (histograms merged
    /// bucket-exactly via [`LogHistogram::merge`]): `(n, mean_s, max_s,
    /// p50_s, p95_s, p99_s)`. All zeros when no fan-out happened.
    pub fn shard_fanout_total(&self) -> (u64, f64, f64, f64, f64, f64) {
        let fan = self.shard_fanout.lock().expect("shard fanout lock");
        let mut h = LogHistogram::new();
        let (mut n, mut weighted_sum, mut max) = (0u64, 0.0f64, 0.0f64);
        for t in fan.values() {
            h.merge(&t.h);
            n += t.w.count();
            weighted_sum += t.w.mean() * t.w.count() as f64;
            max = max.max(t.w.max());
        }
        let mean = if n == 0 { 0.0 } else { weighted_sum / n as f64 };
        (n, mean, max, h.quantile(0.50), h.quantile(0.95), h.quantile(0.99))
    }

    /// Record a request latency.
    pub fn observe_latency(&self, seconds: f64) {
        self.latency.lock().expect("latency lock").push(seconds);
    }

    /// Time a closure and record its latency.
    pub fn time<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.observe_latency(t0.elapsed().as_secs_f64());
        out
    }

    /// Snapshot: (count, mean_s, stddev_s, min_s, max_s).
    pub fn latency_summary(&self) -> (u64, f64, f64, f64, f64) {
        let t = self.latency.lock().expect("latency lock");
        (t.w.count(), t.w.mean(), t.w.stddev(), t.w.min(), t.w.max())
    }

    /// Request-latency quantiles: (p50_s, p95_s, p99_s).
    pub fn latency_quantiles(&self) -> (f64, f64, f64) {
        let t = self.latency.lock().expect("latency lock");
        (t.h.quantile(0.50), t.h.quantile(0.95), t.h.quantile(0.99))
    }

    /// One-line human-readable report.
    pub fn report(&self) -> String {
        let (n, mean, std, min, max) = self.latency_summary();
        let (p50, p95, p99) = self.latency_quantiles();
        let (decisions, mean_at, mean_frac) = self.decision_summary();
        let (kb, kbq, kb_mean) = self.knn_batch_summary();
        let (kb_p50, kb_p95, kb_p99) = self.knn_batch_quantiles();
        let mut proto = format!(" proto_errors: total={}", self.proto_errors_total());
        for code in ErrorCode::ALL {
            let n = self.proto_error_count(code);
            if n > 0 {
                proto.push_str(&format!(" {}={n}", code.as_str()));
            }
        }
        let mut fanout = String::new();
        for (s, t) in self.shard_fanout.lock().expect("shard fanout lock").iter() {
            fanout.push_str(&format!(
                " shard{s}: n={} mean={:.1}ms max={:.1}ms p95={:.1}ms",
                t.w.count(),
                t.w.mean() * 1e3,
                t.w.max() * 1e3,
                t.h.quantile(0.95) * 1e3
            ));
        }
        if !fanout.is_empty() {
            // Fleet-wide aggregate after the per-shard rows (merged
            // histograms, so the quantiles are exact across shards).
            let (fn_, fmean, fmax, fp50, fp95, _) = self.shard_fanout_total();
            fanout.push_str(&format!(
                " all: n={fn_} mean={:.1}ms max={:.1}ms p50={:.1}ms p95={:.1}ms",
                fmean * 1e3,
                fmax * 1e3,
                fp50 * 1e3,
                fp95 * 1e3
            ));
            fanout.insert_str(0, " fanout:");
        }
        let (tr_rec, tr_out, tr_drop, tr_dumps) = self.trace_summary();
        let trace = format!(
            " trace: recorded={tr_rec} sampled_out={tr_out} rec_dropped={tr_drop} rec_dumps={tr_dumps}"
        );
        let (f_retries, f_failovers, f_opens, f_probes, f_degraded) = self.fault_summary();
        let fault = if f_retries + f_failovers + f_opens + f_probes + f_degraded > 0 {
            format!(
                " fault: retries={f_retries} failovers={f_failovers} circuit_opens={f_opens} circuit_probes={f_probes} degraded={f_degraded}"
            )
        } else {
            String::new()
        };
        let (t_upd, t_known, t_at_most, t_served, t_reconf, t_flaps) = self.tuning_summary();
        let tuning = if t_upd + t_known + t_at_most + t_served + t_reconf + t_flaps > 0 {
            format!(
                " tuning: predictor_updates={t_upd} hints_known={t_known} hints_at_most={t_at_most} tunes_served={t_served} reconfigs={t_reconf} suppressed_flaps={t_flaps}"
            )
        } else {
            String::new()
        };
        format!(
            "requests={} comparisons={} batches={} errors={} pool_panics={} latency: n={} mean={:.1}ms sd={:.1}ms min={:.1}ms max={:.1}ms p50={:.1}ms p95={:.1}ms p99={:.1}ms index: {} knn_batch: n={} queries={} mean={:.1}ms p50={:.1}ms p95={:.1}ms p99={:.1}ms stream: opened={} closed={} reaped={} batches={} culled={} decisions={} mean_at={:.0} mean_frac={:.2}{trace}{fault}{tuning}{proto}{fanout}",
            self.requests.load(Ordering::Relaxed),
            self.comparisons.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.pool_panics.load(Ordering::Relaxed),
            n,
            mean * 1e3,
            std * 1e3,
            min * 1e3,
            max * 1e3,
            p50 * 1e3,
            p95 * 1e3,
            p99 * 1e3,
            self.search_stats(),
            kb,
            kbq,
            kb_mean * 1e3,
            kb_p50 * 1e3,
            kb_p95 * 1e3,
            kb_p99 * 1e3,
            self.stream_opened.load(Ordering::Relaxed),
            self.stream_closed.load(Ordering::Relaxed),
            self.stream_reaped.load(Ordering::Relaxed),
            self.stream_batches.load(Ordering::Relaxed),
            self.stream_culled.load(Ordering::Relaxed),
            decisions,
            mean_at,
            mean_frac,
        )
    }

    /// The structured counterpart of [`Metrics::report`]: everything the
    /// string report carries, as one JSON object with pinned field names
    /// (served over the wire as the `metrics` request's body).
    pub fn snapshot(&self) -> Json {
        let (n, mean, std, min, max) = self.latency_summary();
        let (p50, p95, p99) = self.latency_quantiles();
        let (kb, kbq, kb_mean) = self.knn_batch_summary();
        let (kb_p50, kb_p95, kb_p99) = self.knn_batch_quantiles();
        let (decisions, mean_at, mean_frac) = self.decision_summary();
        let s = self.search_stats();
        let (tr_rec, tr_out, tr_drop, tr_dumps) = self.trace_summary();
        let (fan_n, fan_mean, fan_max, fan_p50, fan_p95, fan_p99) = self.shard_fanout_total();
        let mut proto = vec![("total", Json::Num(self.proto_errors_total() as f64))];
        for code in ErrorCode::ALL {
            proto.push((code.as_str(), Json::Num(self.proto_error_count(code) as f64)));
        }
        let fanout = Json::arr(
            self.shard_fanout
                .lock()
                .expect("shard fanout lock")
                .iter()
                .map(|(&shard, t)| {
                    Json::obj(vec![
                        ("shard", Json::Num(shard as f64)),
                        ("n", Json::Num(t.w.count() as f64)),
                        ("mean_ms", Json::Num(t.w.mean() * 1e3)),
                        ("max_ms", Json::Num(t.w.max() * 1e3)),
                        ("p95_ms", Json::Num(t.h.quantile(0.95) * 1e3)),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("requests", Json::Num(self.requests.load(Ordering::Relaxed) as f64)),
            ("comparisons", Json::Num(self.comparisons.load(Ordering::Relaxed) as f64)),
            ("batches", Json::Num(self.batches.load(Ordering::Relaxed) as f64)),
            ("errors", Json::Num(self.errors.load(Ordering::Relaxed) as f64)),
            ("pool_panics", Json::Num(self.pool_panics.load(Ordering::Relaxed) as f64)),
            (
                "index",
                Json::obj(vec![
                    ("candidates", Json::Num(s.candidates as f64)),
                    ("pruned_lb_kim", Json::Num(s.pruned_lb_kim as f64)),
                    ("pruned_lb_paa", Json::Num(s.pruned_lb_paa as f64)),
                    ("pruned_lb_keogh", Json::Num(s.pruned_lb_keogh as f64)),
                    ("abandoned", Json::Num(s.abandoned as f64)),
                    ("dtw_evals", Json::Num(s.dtw_evals as f64)),
                ]),
            ),
            (
                "latency",
                Json::obj(vec![
                    ("n", Json::Num(n as f64)),
                    ("mean_ms", Json::Num(mean * 1e3)),
                    ("sd_ms", Json::Num(std * 1e3)),
                    ("min_ms", Json::Num(min * 1e3)),
                    ("max_ms", Json::Num(max * 1e3)),
                    ("p50_ms", Json::Num(p50 * 1e3)),
                    ("p95_ms", Json::Num(p95 * 1e3)),
                    ("p99_ms", Json::Num(p99 * 1e3)),
                ]),
            ),
            (
                "knn_batch",
                Json::obj(vec![
                    ("batches", Json::Num(kb as f64)),
                    ("queries", Json::Num(kbq as f64)),
                    ("mean_ms", Json::Num(kb_mean * 1e3)),
                    ("p50_ms", Json::Num(kb_p50 * 1e3)),
                    ("p95_ms", Json::Num(kb_p95 * 1e3)),
                    ("p99_ms", Json::Num(kb_p99 * 1e3)),
                ]),
            ),
            (
                "stream",
                Json::obj(vec![
                    ("opened", Json::Num(self.stream_opened.load(Ordering::Relaxed) as f64)),
                    ("closed", Json::Num(self.stream_closed.load(Ordering::Relaxed) as f64)),
                    ("reaped", Json::Num(self.stream_reaped.load(Ordering::Relaxed) as f64)),
                    ("batches", Json::Num(self.stream_batches.load(Ordering::Relaxed) as f64)),
                    ("culled", Json::Num(self.stream_culled.load(Ordering::Relaxed) as f64)),
                    ("decisions", Json::Num(decisions as f64)),
                    ("mean_at", Json::Num(mean_at)),
                    ("mean_frac", Json::Num(mean_frac)),
                ]),
            ),
            (
                "trace",
                Json::obj(vec![
                    ("spans_recorded", Json::Num(tr_rec as f64)),
                    ("spans_sampled_out", Json::Num(tr_out as f64)),
                    ("recorder_dropped", Json::Num(tr_drop as f64)),
                    ("recorder_dumps", Json::Num(tr_dumps as f64)),
                ]),
            ),
            (
                "fault",
                Json::obj(vec![
                    ("retries", Json::Num(self.shard_retries.load(Ordering::Relaxed) as f64)),
                    ("failovers", Json::Num(self.shard_failovers.load(Ordering::Relaxed) as f64)),
                    ("circuit_opens", Json::Num(self.circuit_opens.load(Ordering::Relaxed) as f64)),
                    ("circuit_probes", Json::Num(self.circuit_probes.load(Ordering::Relaxed) as f64)),
                    ("degraded_shards", Json::Num(self.degraded_shards.load(Ordering::Relaxed) as f64)),
                ]),
            ),
            (
                "tuning",
                Json::obj(vec![
                    (
                        "predictor_updates",
                        Json::Num(self.tuning_predictor_updates.load(Ordering::Relaxed) as f64),
                    ),
                    ("hints_known", Json::Num(self.tuning_hints_known.load(Ordering::Relaxed) as f64)),
                    (
                        "hints_at_most",
                        Json::Num(self.tuning_hints_at_most.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "tunes_served",
                        Json::Num(self.tuning_tunes_served.load(Ordering::Relaxed) as f64),
                    ),
                    ("reconfigs", Json::Num(self.tuning_reconfigs.load(Ordering::Relaxed) as f64)),
                    (
                        "suppressed_flaps",
                        Json::Num(self.tuning_suppressed_flaps.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            ),
            ("proto_errors", Json::obj(proto)),
            ("fanout", fanout),
            (
                "fanout_total",
                Json::obj(vec![
                    ("n", Json::Num(fan_n as f64)),
                    ("mean_ms", Json::Num(fan_mean * 1e3)),
                    ("max_ms", Json::Num(fan_max * 1e3)),
                    ("p50_ms", Json::Num(fan_p50 * 1e3)),
                    ("p95_ms", Json::Num(fan_p95 * 1e3)),
                    ("p99_ms", Json::Num(fan_p99 * 1e3)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.inc_comparisons(5);
        m.inc_comparisons(3);
        m.inc_batches();
        m.inc_requests();
        m.inc_errors();
        m.inc_pool_panics();
        assert_eq!(m.comparisons.load(Ordering::Relaxed), 8);
        assert_eq!(m.pool_panics.load(Ordering::Relaxed), 1);
        assert!(m.report().contains("comparisons=8"));
        assert!(m.report().contains("pool_panics=1"));
    }

    #[test]
    fn search_counters_accumulate() {
        let m = Metrics::new();
        let s = SearchStats {
            candidates: 10,
            pruned_lb_kim: 4,
            pruned_lb_paa: 1,
            pruned_lb_keogh: 2,
            abandoned: 1,
            dtw_evals: 2,
        };
        m.record_search(&s);
        m.record_search(&s);
        let total = m.search_stats();
        assert_eq!(total.candidates, 20);
        assert_eq!(total.dtw_evals, 4);
        assert!((total.dtw_fraction() - 0.3).abs() < 1e-12);
        assert!(m.report().contains("candidates=20"), "{}", m.report());
    }

    #[test]
    fn stream_counters_accumulate() {
        let m = Metrics::new();
        m.inc_stream_opened();
        m.inc_stream_opened();
        m.inc_stream_closed();
        m.add_stream_reaped(1);
        m.record_stream_session(&StreamStats {
            samples: 100,
            batches: 10,
            lb_evals: 50,
            dp_evals: 20,
            dp_abandoned: 5,
            culled: 3,
        });
        m.record_stream_decision(60, 0.5);
        m.record_stream_decision(40, 0.3);
        let (n, mean_at, mean_frac) = m.decision_summary();
        assert_eq!(n, 2);
        assert!((mean_at - 50.0).abs() < 1e-9);
        assert!((mean_frac - 0.4).abs() < 1e-9);
        let r = m.report();
        assert!(r.contains("opened=2") && r.contains("culled=3"), "{r}");
    }

    #[test]
    fn knn_batch_counters_accumulate() {
        let m = Metrics::new();
        m.record_knn_batch(8, 0.010);
        m.record_knn_batch(64, 0.030);
        let (batches, queries, mean) = m.knn_batch_summary();
        assert_eq!(batches, 2);
        assert_eq!(queries, 72);
        assert!((mean - 0.020).abs() < 1e-9);
        let r = m.report();
        assert!(r.contains("knn_batch: n=2 queries=72"), "{r}");
    }

    #[test]
    fn proto_error_counters_accumulate_per_code() {
        let m = Metrics::new();
        m.inc_proto_error(ErrorCode::BadRequest);
        m.inc_proto_error(ErrorCode::BadRequest);
        m.inc_proto_error(ErrorCode::UnknownSession);
        assert_eq!(m.proto_error_count(ErrorCode::BadRequest), 2);
        assert_eq!(m.proto_error_count(ErrorCode::UnknownSession), 1);
        assert_eq!(m.proto_error_count(ErrorCode::WrongVersion), 0);
        assert_eq!(m.proto_errors_total(), 3);
        let r = m.report();
        assert!(
            r.contains("proto_errors: total=3 bad_request=2 unknown_session=1"),
            "{r}"
        );
        assert!(!r.contains("wrong_version"), "zero codes stay silent: {r}");
    }

    #[test]
    fn shard_fanout_latency_accumulates_per_shard() {
        let m = Metrics::new();
        m.record_shard_fanout(0, 0.010);
        m.record_shard_fanout(0, 0.030);
        m.record_shard_fanout(2, 0.005);
        let summary = m.shard_fanout_summary();
        assert_eq!(summary.len(), 2);
        assert_eq!(summary[0].0, 0);
        assert_eq!(summary[0].1, 2);
        assert!((summary[0].2 - 0.020).abs() < 1e-9);
        assert!((summary[0].3 - 0.030).abs() < 1e-9);
        assert_eq!(summary[1].0, 2);
        let r = m.report();
        assert!(r.contains("fanout: shard0: n=2"), "{r}");
        assert!(r.contains("shard2: n=1"), "{r}");
    }

    #[test]
    fn latency_stats() {
        let m = Metrics::new();
        m.observe_latency(0.010);
        m.observe_latency(0.020);
        m.observe_latency(0.030);
        let (n, mean, _, min, max) = m.latency_summary();
        assert_eq!(n, 3);
        assert!((mean - 0.020).abs() < 1e-9);
        assert_eq!(min, 0.010);
        assert_eq!(max, 0.030);
    }

    #[test]
    fn report_carries_latency_quantiles() {
        let m = Metrics::new();
        for _ in 0..99 {
            m.observe_latency(0.001);
        }
        m.observe_latency(0.100);
        m.record_knn_batch(4, 0.010);
        let r = m.report();
        assert!(r.contains(" p50="), "{r}");
        assert!(r.contains(" p95="), "{r}");
        assert!(r.contains(" p99="), "{r}");
        let (p50, _, p99) = m.latency_quantiles();
        assert!((0.5e-3..=2e-3).contains(&p50), "p50={p50}");
        assert!((50e-3..=200e-3).contains(&p99), "p99={p99}");
    }

    #[test]
    fn snapshot_pins_the_wire_field_names() {
        let m = Metrics::new();
        m.inc_requests();
        m.inc_comparisons(3);
        m.observe_latency(0.002);
        m.record_knn_batch(8, 0.010);
        m.record_search(&SearchStats {
            candidates: 10,
            pruned_lb_kim: 4,
            pruned_lb_paa: 1,
            pruned_lb_keogh: 2,
            abandoned: 1,
            dtw_evals: 2,
        });
        m.inc_proto_error(ErrorCode::BadRequest);
        m.record_shard_fanout(1, 0.005);
        m.inc_shard_retry();
        m.inc_shard_failover();
        m.inc_circuit_open();
        m.inc_circuit_probe();
        m.inc_degraded_shard();
        m.inc_spans_recorded();
        m.inc_spans_recorded();
        m.inc_spans_sampled_out();
        m.set_recorder_stats(5, 3);
        m.inc_tuning_predictor_update();
        m.inc_tuning_predictor_update();
        m.inc_tuning_hint_known();
        m.inc_tuning_hint_at_most();
        m.inc_tuning_tune_served();
        m.inc_tuning_reconfig();
        m.add_tuning_suppressed(3);
        // Through the serializer, like the real wire path.
        let snap = crate::util::json::Json::parse(&m.snapshot().to_string()).unwrap();
        let num = |path: &[&str]| -> f64 {
            let mut v = &snap;
            for k in path {
                v = v.get(k).unwrap_or_else(|| panic!("missing {path:?}"));
            }
            v.as_f64().unwrap_or_else(|| panic!("non-numeric {path:?}"))
        };
        assert_eq!(num(&["requests"]), 1.0);
        assert_eq!(num(&["comparisons"]), 3.0);
        assert_eq!(num(&["index", "candidates"]), 10.0);
        assert_eq!(num(&["index", "dtw_evals"]), 2.0);
        assert_eq!(num(&["latency", "n"]), 1.0);
        assert!(num(&["latency", "p99_ms"]) > 0.0);
        assert_eq!(num(&["knn_batch", "batches"]), 1.0);
        assert_eq!(num(&["knn_batch", "queries"]), 8.0);
        assert!(num(&["knn_batch", "p50_ms"]) > 0.0);
        assert_eq!(num(&["stream", "opened"]), 0.0);
        assert_eq!(num(&["proto_errors", "total"]), 1.0);
        assert_eq!(num(&["proto_errors", "bad_request"]), 1.0);
        // Every code is always present in the snapshot, even at zero.
        assert_eq!(num(&["proto_errors", "wrong_version"]), 0.0);
        assert_eq!(num(&["trace", "spans_recorded"]), 2.0);
        assert_eq!(num(&["trace", "spans_sampled_out"]), 1.0);
        assert_eq!(num(&["trace", "recorder_dropped"]), 5.0);
        assert_eq!(num(&["trace", "recorder_dumps"]), 3.0);
        assert_eq!(num(&["fault", "retries"]), 1.0);
        assert_eq!(num(&["fault", "failovers"]), 1.0);
        assert_eq!(num(&["fault", "circuit_opens"]), 1.0);
        assert_eq!(num(&["fault", "circuit_probes"]), 1.0);
        assert_eq!(num(&["fault", "degraded_shards"]), 1.0);
        assert_eq!(num(&["tuning", "predictor_updates"]), 2.0);
        assert_eq!(num(&["tuning", "hints_known"]), 1.0);
        assert_eq!(num(&["tuning", "hints_at_most"]), 1.0);
        assert_eq!(num(&["tuning", "tunes_served"]), 1.0);
        assert_eq!(num(&["tuning", "reconfigs"]), 1.0);
        assert_eq!(num(&["tuning", "suppressed_flaps"]), 3.0);
        let fanout = snap.get("fanout").and_then(crate::util::json::Json::as_arr).unwrap();
        assert_eq!(fanout.len(), 1);
        assert_eq!(fanout[0].get("shard").and_then(crate::util::json::Json::as_f64), Some(1.0));
        assert_eq!(fanout[0].get("n").and_then(crate::util::json::Json::as_f64), Some(1.0));
        assert!(fanout[0].get("p95_ms").and_then(crate::util::json::Json::as_f64).unwrap() > 0.0);
        assert_eq!(num(&["fanout_total", "n"]), 1.0);
        assert!(num(&["fanout_total", "p50_ms"]) > 0.0);
    }

    #[test]
    fn trace_counters_land_in_report_and_fanout_total_merges() {
        let m = Metrics::new();
        m.inc_spans_recorded();
        m.inc_spans_sampled_out();
        m.inc_spans_sampled_out();
        m.set_recorder_stats(7, 1);
        let r = m.report();
        assert!(
            r.contains("trace: recorded=1 sampled_out=2 rec_dropped=7 rec_dumps=1"),
            "{r}"
        );

        // The aggregate is the histogram-merge of the per-shard tracks.
        m.record_shard_fanout(0, 0.001);
        m.record_shard_fanout(0, 0.001);
        m.record_shard_fanout(1, 0.100);
        let (n, mean, max, p50, p95, _) = m.shard_fanout_total();
        assert_eq!(n, 3);
        assert!((mean - 0.034).abs() < 1e-9, "weighted mean, mean={mean}");
        assert!((max - 0.100).abs() < 1e-12);
        assert!((0.5e-3..=2e-3).contains(&p50), "p50={p50}");
        assert!((50e-3..=200e-3).contains(&p95), "p95={p95}");
        let r = m.report();
        assert!(r.contains("all: n=3"), "{r}");
    }

    #[test]
    fn fault_counters_accumulate_and_stay_silent_at_zero() {
        let m = Metrics::new();
        assert!(!m.report().contains("fault:"), "{}", m.report());
        m.inc_shard_retry();
        m.inc_shard_retry();
        m.inc_shard_failover();
        m.inc_circuit_open();
        m.inc_circuit_probe();
        m.inc_degraded_shard();
        assert_eq!(m.fault_summary(), (2, 1, 1, 1, 1));
        let r = m.report();
        assert!(
            r.contains("fault: retries=2 failovers=1 circuit_opens=1 circuit_probes=1 degraded=1"),
            "{r}"
        );
    }

    #[test]
    fn tuning_counters_accumulate_and_stay_silent_at_zero() {
        let m = Metrics::new();
        assert!(!m.report().contains("tuning:"), "{}", m.report());
        m.inc_tuning_predictor_update();
        m.inc_tuning_predictor_update();
        m.inc_tuning_hint_known();
        m.inc_tuning_hint_at_most();
        m.inc_tuning_tune_served();
        m.inc_tuning_reconfig();
        m.add_tuning_suppressed(2);
        assert_eq!(m.tuning_summary(), (2, 1, 1, 1, 1, 2));
        let r = m.report();
        assert!(
            r.contains(
                "tuning: predictor_updates=2 hints_known=1 hints_at_most=1 tunes_served=1 reconfigs=1 suppressed_flaps=2"
            ),
            "{r}"
        );
    }

    #[test]
    fn concurrent_updates() {
        let m = Arc::new(Metrics::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let m = Arc::clone(&m);
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.inc_comparisons(1);
                        m.observe_latency(0.001);
                    }
                });
            }
        });
        assert_eq!(m.comparisons.load(Ordering::Relaxed), 8000);
        assert_eq!(m.latency_summary().0, 8000);
    }
}
