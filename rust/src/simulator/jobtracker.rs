//! FIFO JobTracker: pending queues, reduce slow-start, wave accounting.

use std::collections::VecDeque;

/// Scheduling state for one job (Hadoop 0.20 FIFO semantics).
#[derive(Debug)]
pub struct JobTracker {
    pending_maps: VecDeque<usize>,
    pending_reduces: VecDeque<usize>,
    pub total_maps: usize,
    pub total_reduces: usize,
    pub completed_maps: usize,
    pub completed_reduces: usize,
    slowstart: f64,
}

impl JobTracker {
    pub fn new(num_maps: usize, num_reduces: usize, slowstart: f64) -> JobTracker {
        JobTracker {
            pending_maps: (0..num_maps).collect(),
            pending_reduces: (0..num_reduces).collect(),
            total_maps: num_maps,
            total_reduces: num_reduces,
            completed_maps: 0,
            completed_reduces: 0,
            slowstart: slowstart.clamp(0.0, 1.0),
        }
    }

    /// Maps needed before reducers may launch.
    fn slowstart_threshold(&self) -> usize {
        ((self.slowstart * self.total_maps as f64).ceil() as usize).min(self.total_maps)
    }

    /// True once reduce tasks are allowed to start.
    pub fn reducers_eligible(&self) -> bool {
        self.completed_maps >= self.slowstart_threshold()
    }

    /// Pop the next pending map task.
    pub fn next_map(&mut self) -> Option<usize> {
        self.pending_maps.pop_front()
    }

    /// Pop the next pending reduce task, honouring slow-start.
    pub fn next_reduce(&mut self) -> Option<usize> {
        if self.reducers_eligible() {
            self.pending_reduces.pop_front()
        } else {
            None
        }
    }

    pub fn has_pending_maps(&self) -> bool {
        !self.pending_maps.is_empty()
    }

    pub fn has_pending_reduces(&self) -> bool {
        !self.pending_reduces.is_empty()
    }

    pub fn on_map_complete(&mut self) {
        self.completed_maps += 1;
        debug_assert!(self.completed_maps <= self.total_maps);
    }

    pub fn on_reduce_complete(&mut self) {
        self.completed_reduces += 1;
        debug_assert!(self.completed_reduces <= self.total_reduces);
    }

    pub fn all_done(&self) -> bool {
        self.completed_maps == self.total_maps && self.completed_reduces == self.total_reduces
    }

    /// Number of map waves on a cluster with `slots` map slots.
    pub fn map_waves(&self, slots: usize) -> usize {
        self.total_maps.div_ceil(slots.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut jt = JobTracker::new(3, 2, 0.0);
        assert_eq!(jt.next_map(), Some(0));
        assert_eq!(jt.next_map(), Some(1));
        assert_eq!(jt.next_map(), Some(2));
        assert_eq!(jt.next_map(), None);
    }

    #[test]
    fn slowstart_gates_reducers() {
        let mut jt = JobTracker::new(20, 2, 0.05);
        assert!(!jt.reducers_eligible());
        assert_eq!(jt.next_reduce(), None);
        jt.on_map_complete();
        assert!(jt.reducers_eligible()); // ceil(0.05*20)=1
        assert_eq!(jt.next_reduce(), Some(0));
    }

    #[test]
    fn slowstart_zero_starts_immediately() {
        let mut jt = JobTracker::new(5, 1, 0.0);
        assert!(jt.reducers_eligible());
        assert_eq!(jt.next_reduce(), Some(0));
    }

    #[test]
    fn all_done_tracking() {
        let mut jt = JobTracker::new(2, 1, 0.0);
        assert!(!jt.all_done());
        jt.on_map_complete();
        jt.on_map_complete();
        jt.on_reduce_complete();
        assert!(jt.all_done());
    }

    #[test]
    fn wave_math() {
        let jt = JobTracker::new(11, 1, 0.05);
        assert_eq!(jt.map_waves(2), 6);
        assert_eq!(jt.map_waves(4), 3);
        assert_eq!(jt.map_waves(16), 1);
    }
}
