//! PJRT client: compile AOT artifacts once, execute them on demand.
//!
//! [`Runtime`] is **not** `Send` (the `xla` crate's `PjRtClient` is
//! `Rc`-based); [`super::executor::RuntimeHandle`] wraps it in a dedicated
//! service thread for the multi-threaded coordinator.
//!
//! The `xla` PJRT bindings are not on crates.io (the deployment image
//! vendors them), so the real client compiles only when the build also
//! sets `--cfg pjrt_vendored` (RUSTFLAGS) *and* adds the dependency —
//! see `Cargo.toml`. With the `pjrt` cargo feature alone, a stub
//! [`Runtime`] with the same API always fails to load, keeping
//! `--all-features` builds (CI clippy) compiling while every runtime
//! call falls back to the bit-compatible pure-Rust path.

#[cfg(all(feature = "pjrt", pjrt_vendored))]
use super::artifacts::{EntryKind, Manifest};
#[cfg(all(feature = "pjrt", pjrt_vendored))]
use anyhow::{anyhow, Context, Result};
#[cfg(all(feature = "pjrt", pjrt_vendored))]
use std::collections::BTreeMap;
#[cfg(all(feature = "pjrt", pjrt_vendored))]
use std::path::Path;

/// A padded, fixed-bucket f32 series plus its true length.
#[derive(Debug, Clone, PartialEq)]
pub struct Padded {
    pub data: Vec<f32>,
    pub len: usize,
}

impl Padded {
    /// Pad (or linearly resample, if longer than `bucket`) to `bucket`.
    pub fn fit(series: &[f64], bucket: usize) -> Padded {
        let (vals, len) = if series.len() <= bucket {
            (series.to_vec(), series.len())
        } else {
            (crate::signal::resample::linear(series, bucket), bucket)
        };
        let mut data: Vec<f32> = vals.iter().map(|&v| v as f32).collect();
        data.resize(bucket, 0.0);
        Padded { data, len }
    }

    /// The valid prefix as f64.
    pub fn valid(&self) -> Vec<f64> {
        self.data[..self.len].iter().map(|&v| v as f64).collect()
    }
}

/// Result of a batched DTW execution.
#[derive(Debug, Clone)]
pub struct BatchOutput {
    /// Raw DTW distances per batch lane.
    pub dists: Vec<f32>,
    /// Traceback choices, `batch * len * len`, row-major.
    pub choices: Vec<i8>,
    /// Bucket length the lane matrices are sized for.
    pub len: usize,
}

impl BatchOutput {
    /// The `b`-th lane's choice matrix.
    pub fn lane_choices(&self, b: usize) -> &[i8] {
        &self.choices[b * self.len * self.len..(b + 1) * self.len * self.len]
    }
}

/// Stub runtime for `pjrt` builds without the vendored `xla` bindings:
/// same API as the real [`Runtime`], but loading always fails, so
/// [`super::executor::RuntimeService::start`] reports the runtime as
/// unavailable and callers fall back to pure Rust. The post-load methods
/// are unreachable (no stub can be constructed).
#[cfg(all(feature = "pjrt", not(pjrt_vendored)))]
pub enum Runtime {}

#[cfg(all(feature = "pjrt", not(pjrt_vendored)))]
impl Runtime {
    pub fn load(dir: &std::path::Path) -> anyhow::Result<Runtime> {
        Err(anyhow::anyhow!(
            "pjrt feature enabled but the xla backend is not vendored \
             (build with RUSTFLAGS=\"--cfg pjrt_vendored\" and an xla dependency); \
             cannot load artifacts from {}",
            dir.display()
        ))
    }

    pub fn manifest(&self) -> &super::artifacts::Manifest {
        match *self {}
    }

    pub fn preprocess(&self, _series: &Padded) -> anyhow::Result<Padded> {
        match *self {}
    }

    pub fn dtw_batch(&self, _query: &Padded, _refs: &[Padded]) -> anyhow::Result<BatchOutput> {
        match *self {}
    }

    pub fn match_one(
        &self,
        _raw_query: &Padded,
        _refs: &[Padded],
    ) -> anyhow::Result<(Padded, BatchOutput)> {
        match *self {}
    }
}

/// Compiled executables keyed by artifact name.
///
/// Only compiled with the `pjrt` cargo feature plus the `pjrt_vendored`
/// cfg (which needs the `xla` PJRT bindings — see `Cargo.toml`); the
/// default build uses the pure-Rust fallbacks everywhere and
/// [`super::executor::RuntimeService::start`] reports the runtime as
/// unavailable.
#[cfg(all(feature = "pjrt", pjrt_vendored))]
pub struct Runtime {
    manifest: Manifest,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(all(feature = "pjrt", pjrt_vendored))]
impl Runtime {
    /// Load every artifact in `dir` and compile it on the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = BTreeMap::new();
        for entry in &manifest.entries {
            let path = manifest.dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", entry.name))?;
            executables.insert(entry.name.clone(), exe);
        }
        log::info!(
            "runtime: compiled {} artifacts from {}",
            executables.len(),
            dir.display()
        );
        Ok(Runtime {
            manifest,
            executables,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn exe(&self, kind: EntryKind, len: usize) -> Result<&xla::PjRtLoadedExecutable> {
        let entry = self
            .manifest
            .entry(kind, len)
            .ok_or_else(|| anyhow!("no artifact for {kind:?} at bucket {len}"))?;
        self.executables
            .get(&entry.name)
            .ok_or_else(|| anyhow!("artifact {} not compiled", entry.name))
    }

    /// Chebyshev de-noise + normalize via the `preprocess_L` artifact.
    pub fn preprocess(&self, series: &Padded) -> Result<Padded> {
        let bucket = series.data.len();
        let exe = self.exe(EntryKind::Preprocess, bucket)?;
        let x = xla::Literal::vec1(&series.data);
        let n = xla::Literal::vec1(&[series.len as i32]);
        let result = exe.execute::<xla::Literal>(&[x, n])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(Padded {
            data: out.to_vec::<f32>()?,
            len: series.len,
        })
    }

    /// Batched DTW via the `dtw_batch_BxL` artifact. `refs` must have
    /// exactly the manifest batch size (pad with dummies and ignore).
    pub fn dtw_batch(&self, query: &Padded, refs: &[Padded]) -> Result<BatchOutput> {
        let bucket = query.data.len();
        let b = self.manifest.batch;
        if refs.len() != b {
            return Err(anyhow!("dtw_batch needs exactly {b} refs, got {}", refs.len()));
        }
        let exe = self.exe(EntryKind::DtwBatch, bucket)?;
        let (dists, choices) = self.run_batched(exe, None, query, refs, bucket)?;
        Ok(BatchOutput {
            dists,
            choices,
            len: bucket,
        })
    }

    /// Fused preprocess+DTW via `match_one_BxL`. Returns the preprocessed
    /// query along with the batch output.
    pub fn match_one(&self, raw_query: &Padded, refs: &[Padded]) -> Result<(Padded, BatchOutput)> {
        let bucket = raw_query.data.len();
        let b = self.manifest.batch;
        if refs.len() != b {
            return Err(anyhow!("match_one needs exactly {b} refs, got {}", refs.len()));
        }
        let exe = self.exe(EntryKind::MatchOne, bucket)?;

        let mut ys = Vec::with_capacity(b * bucket);
        let mut nys = Vec::with_capacity(b);
        for r in refs {
            anyhow::ensure!(r.data.len() == bucket, "ref bucket mismatch");
            ys.extend_from_slice(&r.data);
            nys.push(r.len as i32);
        }
        let args = [
            xla::Literal::vec1(&raw_query.data),
            xla::Literal::vec1(&ys).reshape(&[b as i64, bucket as i64])?,
            xla::Literal::vec1(&[raw_query.len as i32]),
            xla::Literal::vec1(&nys),
        ];
        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (q, dists, choices) = result.to_tuple3()?;
        Ok((
            Padded {
                data: q.to_vec::<f32>()?,
                len: raw_query.len,
            },
            BatchOutput {
                dists: dists.to_vec::<f32>()?,
                choices: choices.to_vec::<i8>()?,
                len: bucket,
            },
        ))
    }

    fn run_batched(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        _q_pre: Option<()>,
        query: &Padded,
        refs: &[Padded],
        bucket: usize,
    ) -> Result<(Vec<f32>, Vec<i8>)> {
        let b = refs.len();
        let mut ys = Vec::with_capacity(b * bucket);
        let mut nys = Vec::with_capacity(b);
        for r in refs {
            anyhow::ensure!(r.data.len() == bucket, "ref bucket mismatch");
            ys.extend_from_slice(&r.data);
            nys.push(r.len as i32);
        }
        let args = [
            xla::Literal::vec1(&query.data),
            xla::Literal::vec1(&ys).reshape(&[b as i64, bucket as i64])?,
            xla::Literal::vec1(&[query.len as i32]),
            xla::Literal::vec1(&nys),
        ];
        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (dists, choices) = result.to_tuple2()?;
        Ok((dists.to_vec::<f32>()?, choices.to_vec::<i8>()?))
    }
}
