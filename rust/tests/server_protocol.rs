//! Wire-protocol integration tests: golden v1 byte-compatibility through
//! the v2 dispatch path, malformed-input hardening of the read loop,
//! session lifecycle across reconnects, and client pipelining.

use mrtuner::client::MrtunerClient;
use mrtuner::coordinator::metrics::Metrics;
use mrtuner::coordinator::server::{handle_line, MatchServer, ServerState};
use mrtuner::database::profile::ProfileEntry;
use mrtuner::index::{IndexedDb, SearchStats};
use mrtuner::protocol::Request;
use mrtuner::simulator::job::JobConfig;
use mrtuner::streaming::SessionManager;
use mrtuner::util::json::Json;
use mrtuner::workloads::AppId;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::time::Duration;

fn raw_wave(freq: f64) -> Vec<f64> {
    (0..64)
        .map(|i| (0.5 + 0.4 * ((i as f64) * freq).sin()).clamp(0.0, 1.0))
        .collect()
}

fn state_with_db() -> ServerState {
    let mut db = IndexedDb::new();
    db.insert(ProfileEntry {
        app: AppId::WordCount,
        config: JobConfig::new(4, 2, 10.0, 20.0),
        series: mrtuner::signal::preprocess(&raw_wave(0.2)),
        raw_len: 64,
        completion_secs: 100.0,
    });
    db.insert(ProfileEntry {
        app: AppId::TeraSort,
        config: JobConfig::new(4, 2, 10.0, 20.0),
        series: mrtuner::signal::preprocess(&raw_wave(0.55)),
        raw_len: 64,
        completion_secs: 80.0,
    });
    ServerState {
        db,
        runtime: None,
        metrics: Metrics::new(),
        sessions: SessionManager::new(),
        tracer: mrtuner::trace::TraceHandle::disabled(),
        recorder: None,
        predictors: Default::default(),
    }
}

fn spawn_server(state: ServerState) -> (std::net::SocketAddr, impl FnOnce()) {
    let server = MatchServer::bind("127.0.0.1:0", state).unwrap();
    let addr = server.local_addr().unwrap();
    let stop = server.stop_flag();
    let handle = std::thread::spawn(move || server.serve_with(2, Duration::from_millis(50)));
    let shutdown = move || {
        stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(addr);
        handle.join().unwrap().unwrap();
    };
    (addr, shutdown)
}

// ---------------------------------------------------------------------
// Golden v1 compatibility: the legacy renderer below is the pre-envelope
// server's handler code, kept verbatim as the oracle. Every documented v1
// command line must answer byte-identically through the new typed path.
// ---------------------------------------------------------------------

mod legacy {
    use super::*;
    use mrtuner::coordinator::batcher::{prepare_query, similarities_auto};
    use mrtuner::dtw::corr::MATCH_THRESHOLD;
    use mrtuner::streaming::{
        DecisionPolicy, FinalLen, StreamDecision, StreamSession, TopEntry, MAX_RETAINED,
        MAX_STREAM_LEN,
    };
    use mrtuner::util::pool::default_workers;

    pub fn handle_request(line: &str, state: &ServerState) -> anyhow::Result<Json> {
        use anyhow::anyhow;
        let req = Json::parse(line).map_err(|e| anyhow!("bad json: {e}"))?;
        match req.get("cmd").and_then(Json::as_str) {
            Some("ping") => Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("pong", Json::Bool(true)),
            ])),
            Some("stats") => Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("report", Json::Str(state.metrics.report())),
                ("db_entries", Json::Num(state.db.len() as f64)),
                ("live_sessions", Json::Num(state.sessions.len() as f64)),
            ])),
            Some("apps") => Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "apps",
                    Json::arr(
                        state
                            .db
                            .apps()
                            .iter()
                            .map(|a| Json::Str(a.name().to_string()))
                            .collect(),
                    ),
                ),
            ])),
            Some("match") => handle_match(&req, state),
            Some("knn") => handle_knn(&req, state),
            Some("knn_batch") => handle_knn_batch(&req, state),
            Some("stream_open") => handle_stream_open(&req, state),
            Some("stream_feed") => handle_stream_feed(&req, state),
            Some("stream_poll") => handle_stream_poll(&req, state),
            Some("stream_poll_all") => handle_stream_poll_all(&req, state),
            Some("stream_close") => handle_stream_close(&req, state),
            _ => Err(anyhow!("unknown cmd")),
        }
    }

    fn parse_series(req: &Json) -> anyhow::Result<Vec<f64>> {
        use anyhow::anyhow;
        let series = req
            .get("series")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing series"))?
            .iter()
            .filter_map(Json::as_f64)
            .collect::<Vec<f64>>();
        if series.len() < 4 {
            return Err(anyhow!("series too short"));
        }
        Ok(series)
    }

    fn parse_config(v: &Json) -> anyhow::Result<JobConfig> {
        use anyhow::anyhow;
        let num = |k: &str| -> anyhow::Result<f64> {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("config missing {k}"))
        };
        Ok(JobConfig::new(
            num("mappers")? as usize,
            num("reducers")? as usize,
            num("split_mb")?,
            num("input_mb")?,
        ))
    }

    fn parse_session_id(req: &Json) -> anyhow::Result<u64> {
        use anyhow::anyhow;
        req.get("session")
            .and_then(Json::as_usize)
            .map(|id| id as u64)
            .ok_or_else(|| anyhow!("missing session id"))
    }

    fn decision_json(d: &StreamDecision) -> Json {
        Json::obj(vec![
            ("app", Json::Str(d.app.name().to_string())),
            ("config", Json::Str(d.config.label())),
            ("entry", Json::Num(d.entry as f64)),
            ("distance", Json::Num(d.distance)),
            ("similarity", Json::Num(d.similarity)),
            ("at_sample", Json::Num(d.at_sample as f64)),
            ("fraction", Json::Num(d.fraction)),
        ])
    }

    fn handle_stream_open(req: &Json, state: &ServerState) -> anyhow::Result<Json> {
        let config = match req.get("config") {
            Some(c) => Some(parse_config(c)?),
            None => None,
        };
        let final_len = match req.get("final_len").and_then(Json::as_usize) {
            Some(n) if n > 0 => FinalLen::Known(n.min(MAX_RETAINED)),
            _ => FinalLen::AtMost(
                req.get("max_len")
                    .and_then(Json::as_usize)
                    .unwrap_or(MAX_STREAM_LEN)
                    .clamp(1, MAX_RETAINED),
            ),
        };
        let mut policy = DecisionPolicy::default();
        if let Some(f) = req.get("min_fraction").and_then(Json::as_f64) {
            policy.min_fraction = f.clamp(0.0, 2.0);
        }
        if let Some(m) = req.get("margin").and_then(Json::as_f64) {
            policy.margin = m.max(1.0);
        }
        if let Some(s) = req.get("min_samples").and_then(Json::as_usize) {
            policy.min_samples = s;
        }
        let session = StreamSession::open(&state.db, config.as_ref(), final_len, policy);
        let candidates = session.candidates();
        let id = state.sessions.open(session);
        state.metrics.inc_stream_opened();
        Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("session", Json::Num(id as f64)),
            ("candidates", Json::Num(candidates as f64)),
        ]))
    }

    fn handle_stream_feed(req: &Json, state: &ServerState) -> anyhow::Result<Json> {
        use anyhow::anyhow;
        let id = parse_session_id(req)?;
        let samples: Vec<f64> = req
            .get("samples")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing samples"))?
            .iter()
            .filter_map(Json::as_f64)
            .collect();
        if samples.is_empty() {
            return Err(anyhow!("empty samples"));
        }
        let (_decided_now, decision, observed, live) = state.sessions.with(id, |s| {
            let had = s.decision().is_some();
            s.push(&state.db, &samples);
            let d = s.decision().cloned();
            (d.is_some() && !had, d, s.observed(), s.live_candidates())
        })?;
        Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("observed", Json::Num(observed as f64)),
            ("live_candidates", Json::Num(live as f64)),
            (
                "decision",
                decision.as_ref().map(decision_json).unwrap_or(Json::Null),
            ),
        ]))
    }

    fn top_json(top: &[TopEntry]) -> Json {
        Json::arr(
            top.iter()
                .map(|t| {
                    Json::obj(vec![
                        ("app", Json::Str(t.app.name().to_string())),
                        ("config", Json::Str(t.config.label())),
                        ("entry", Json::Num(t.entry as f64)),
                        ("distance", t.distance.map(Json::Num).unwrap_or(Json::Null)),
                        ("lower_bound", Json::Num(t.lower_bound)),
                    ])
                })
                .collect(),
        )
    }

    fn handle_stream_poll(req: &Json, state: &ServerState) -> anyhow::Result<Json> {
        let id = parse_session_id(req)?;
        let k = req.get("k").and_then(Json::as_usize).unwrap_or(3).clamp(1, 20);
        let (top, decision, observed, live, culled) = state.sessions.with(id, |s| {
            (
                s.top(&state.db, k),
                s.decision().cloned(),
                s.observed(),
                s.live_candidates(),
                s.stats().culled,
            )
        })?;
        Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("observed", Json::Num(observed as f64)),
            ("live_candidates", Json::Num(live as f64)),
            ("culled", Json::Num(culled as f64)),
            ("top", top_json(&top)),
            (
                "decision",
                decision.as_ref().map(decision_json).unwrap_or(Json::Null),
            ),
        ]))
    }

    fn handle_stream_poll_all(req: &Json, state: &ServerState) -> anyhow::Result<Json> {
        let k = req.get("k").and_then(Json::as_usize).unwrap_or(3).clamp(1, 20);
        let polls = state.sessions.poll_all(&state.db, k);
        let rows = polls
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("session", Json::Num(p.id as f64)),
                    ("observed", Json::Num(p.observed as f64)),
                    ("live_candidates", Json::Num(p.live_candidates as f64)),
                    ("culled", Json::Num(p.culled as f64)),
                    ("top", top_json(&p.top)),
                    (
                        "decision",
                        p.decision.as_ref().map(decision_json).unwrap_or(Json::Null),
                    ),
                ])
            })
            .collect();
        Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("sessions", Json::arr(rows)),
        ]))
    }

    fn handle_stream_close(req: &Json, state: &ServerState) -> anyhow::Result<Json> {
        let id = parse_session_id(req)?;
        let session = state.sessions.close(id)?;
        state.metrics.inc_stream_closed();
        state.metrics.record_stream_session(&session.stats());
        let (neighbors, stats) = session.finalize(&state.db, 1);
        state.metrics.record_search(&stats);
        let entries = state.db.entries();
        let final_json = match neighbors.first() {
            Some(nb) => {
                let e = &entries[nb.index];
                let q = prepare_query(session.raw());
                let sim = mrtuner::dtw::corr::similarity_percent_banded(&q, &e.series);
                Json::obj(vec![
                    ("app", Json::Str(e.app.name().to_string())),
                    ("config", Json::Str(e.config_key())),
                    ("entry", Json::Num(nb.index as f64)),
                    ("distance", Json::Num(nb.distance)),
                    ("similarity", Json::Num(sim)),
                    ("matched", Json::Bool(sim >= MATCH_THRESHOLD)),
                ])
            }
            None => Json::Null,
        };
        Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("observed", Json::Num(session.observed() as f64)),
            ("final", final_json),
            (
                "decision",
                session.decision().map(decision_json).unwrap_or(Json::Null),
            ),
        ]))
    }

    fn stats_json(stats: &SearchStats) -> Json {
        Json::obj(vec![
            ("candidates", Json::Num(stats.candidates as f64)),
            ("pruned_lb_kim", Json::Num(stats.pruned_lb_kim as f64)),
            ("pruned_lb_paa", Json::Num(stats.pruned_lb_paa as f64)),
            ("pruned_lb_keogh", Json::Num(stats.pruned_lb_keogh as f64)),
            ("abandoned", Json::Num(stats.abandoned as f64)),
            ("dtw_evals", Json::Num(stats.dtw_evals as f64)),
        ])
    }

    fn neighbor_json(state: &ServerState, q: &[f64], nb: &mrtuner::index::Neighbor) -> Json {
        let e = &state.db.entries()[nb.index];
        Json::obj(vec![
            ("app", Json::Str(e.app.name().to_string())),
            ("config", Json::Str(e.config_key())),
            ("distance", Json::Num(nb.distance)),
            (
                "similarity",
                Json::Num(mrtuner::dtw::corr::similarity_percent_banded(q, &e.series)),
            ),
        ])
    }

    fn handle_knn(req: &Json, state: &ServerState) -> anyhow::Result<Json> {
        let series = parse_series(req)?;
        let k = req.get("k").and_then(Json::as_usize).unwrap_or(1).clamp(1, 100);
        let q = prepare_query(&series);
        let (neighbors, stats) = match req.get("config") {
            Some(cfg) => state.db.knn_in_config(&q, &parse_config(cfg)?.label(), k),
            None => state.db.knn_parallel(&q, k, default_workers()),
        };
        state.metrics.record_search(&stats);
        state.metrics.inc_comparisons(stats.dtw_evals);
        let results = neighbors.iter().map(|nb| neighbor_json(state, &q, nb)).collect();
        Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("neighbors", Json::arr(results)),
            ("stats", stats_json(&stats)),
        ]))
    }

    fn handle_knn_batch(req: &Json, state: &ServerState) -> anyhow::Result<Json> {
        use anyhow::anyhow;
        let queries_json = req
            .get("queries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing queries"))?;
        if queries_json.is_empty() {
            return Err(anyhow!("empty queries"));
        }
        let k = req.get("k").and_then(Json::as_usize).unwrap_or(1).clamp(1, 100);
        let mut prepared: Vec<Vec<f64>> = Vec::with_capacity(queries_json.len());
        for (qi, qj) in queries_json.iter().enumerate() {
            let series: Vec<f64> = qj
                .as_arr()
                .ok_or_else(|| anyhow!("query {qi}: not an array"))?
                .iter()
                .filter_map(Json::as_f64)
                .collect();
            if series.len() < 4 {
                return Err(anyhow!("query {qi}: series too short"));
            }
            prepared.push(prepare_query(&series));
        }
        let qrefs: Vec<&[f64]> = prepared.iter().map(Vec::as_slice).collect();
        let t0 = std::time::Instant::now();
        let results = match req.get("config") {
            Some(cfg) => state
                .db
                .knn_batch_in_config(&qrefs, &parse_config(cfg)?.label(), k),
            None => state.db.knn_batch(&qrefs, k),
        };
        state
            .metrics
            .record_knn_batch(qrefs.len() as u64, t0.elapsed().as_secs_f64());
        let mut merged = SearchStats::default();
        let rows = results
            .iter()
            .zip(&prepared)
            .map(|((neighbors, stats), q)| {
                merged.merge(stats);
                Json::obj(vec![
                    (
                        "neighbors",
                        Json::arr(neighbors.iter().map(|nb| neighbor_json(state, q, nb)).collect()),
                    ),
                    ("stats", stats_json(stats)),
                ])
            })
            .collect();
        state.metrics.record_search(&merged);
        state.metrics.inc_comparisons(merged.dtw_evals);
        Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("results", Json::arr(rows)),
            ("stats", stats_json(&merged)),
        ]))
    }

    fn handle_match(req: &Json, state: &ServerState) -> anyhow::Result<Json> {
        use anyhow::anyhow;
        let series = parse_series(req)?;
        let config = parse_config(
            req.get("config")
                .ok_or_else(|| anyhow!("match: missing config"))?,
        )?;
        let refs = state.db.by_config(&config.label());
        let ref_series: Vec<Vec<f64>> = refs.iter().map(|e| e.series.clone()).collect();
        let sims = similarities_auto(state.runtime.as_ref(), &series, &ref_series);
        state.metrics.inc_comparisons(sims.len() as u64);
        let mut results = Vec::new();
        let mut best: Option<(&str, f64)> = None;
        for (e, s) in refs.iter().zip(&sims) {
            results.push(Json::obj(vec![
                ("app", Json::Str(e.app.name().to_string())),
                ("similarity", Json::Num(*s)),
            ]));
            if best.map_or(true, |(_, bs)| *s > bs) {
                best = Some((e.app.name(), *s));
            }
        }
        let (match_app, match_sim) = match best {
            Some((a, s)) if s >= MATCH_THRESHOLD => (Json::Str(a.to_string()), Json::Num(s)),
            Some((_, s)) => (Json::Null, Json::Num(s)),
            None => (Json::Null, Json::Num(0.0)),
        };
        Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("results", Json::arr(results)),
            ("match", match_app),
            ("best_similarity", match_sim),
        ]))
    }
}

/// What the pre-envelope connection loop wrote for an error.
fn legacy_error_json(e: &anyhow::Error) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(format!("{e:#}"))),
    ])
}

#[test]
fn golden_v1_commands_answer_byte_identically() {
    // Twin states, driven in lockstep: `new` answers through the typed v2
    // dispatch path, `old` through the verbatim legacy handlers above.
    let new_state = state_with_db();
    let old_state = state_with_db();
    let series = Json::nums(&raw_wave(0.2)).to_string();
    let q2 = Json::nums(&raw_wave(0.55)).to_string();
    let chunk = Json::nums(&raw_wave(0.2)[..16]).to_string();
    let config = r#"{"input_mb":20,"mappers":4,"reducers":2,"split_mb":10}"#;
    // Every documented command from the server.rs header, plus error
    // cases; stats goes first so both reports are all-zero deterministic.
    let lines = vec![
        r#"{"cmd":"ping"}"#.to_string(),
        r#"{"cmd":"stats"}"#.to_string(),
        r#"{"cmd":"apps"}"#.to_string(),
        format!(r#"{{"cmd":"match","series":{series},"config":{config}}}"#),
        format!(r#"{{"cmd":"knn","series":{series},"k":2}}"#),
        format!(r#"{{"cmd":"knn","series":{series},"k":5,"config":{config}}}"#),
        format!(r#"{{"cmd":"knn_batch","queries":[{series},{q2}],"k":1}}"#),
        format!(r#"{{"cmd":"stream_open","config":{config},"final_len":64}}"#),
        format!(r#"{{"cmd":"stream_feed","session":1,"samples":{chunk}}}"#),
        r#"{"cmd":"stream_poll","session":1,"k":2}"#.to_string(),
        r#"{"cmd":"stream_poll_all","k":2}"#.to_string(),
        r#"{"cmd":"stream_close","session":1}"#.to_string(),
        // Error paths must keep the legacy error shape byte-for-byte too.
        "not json".to_string(),
        r#"{"cmd":"nope"}"#.to_string(),
        r#"{"cmd":"match"}"#.to_string(),
        r#"{"cmd":"knn","series":[1,2]}"#.to_string(),
        r#"{"cmd":"stream_poll","session":99}"#.to_string(),
    ];
    for line in &lines {
        let got = handle_line(line, &new_state).to_string();
        let want = match legacy::handle_request(line, &old_state) {
            Ok(v) => v.to_string(),
            Err(e) => legacy_error_json(&e).to_string(),
        };
        assert_eq!(got, want, "v1 byte compatibility broke for line: {line}");
    }
}

// ---------------------------------------------------------------------
// Malformed-input hardening: every garbage line gets a structured error
// response over the SAME connection — never a drop, never a panic — and
// rejects are counted in the metrics report.
// ---------------------------------------------------------------------

#[test]
fn malformed_lines_get_structured_errors_not_disconnects() {
    let (addr, shutdown) = spawn_server(state_with_db());
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    let garbage: Vec<Vec<u8>> = vec![
        b"not json".to_vec(),
        b"{".to_vec(),
        b"[1,2".to_vec(),
        b"\"unterminated".to_vec(),
        b"123".to_vec(),
        b"null".to_vec(),
        b"{\"cmd\":\"nope\"}".to_vec(),
        b"{\"cmd\":\"knn\"}".to_vec(),
        b"{\"v\":99,\"id\":1,\"type\":\"ping\"}".to_vec(),
        b"{\"v\":2,\"id\":1,\"type\":\"gibberish\"}".to_vec(),
        // Deep nesting: must be a parse error, not a recursion blow-up.
        "[".repeat(20_000).into_bytes(),
        "{\"a\":".repeat(10_000).into_bytes(),
        // Invalid UTF-8: rejected, connection kept.
        vec![0xff, 0xfe, 0x80, b'x'],
        // Control bytes that ARE valid UTF-8.
        vec![0x00, 0x01, 0x02],
        // A line past MAX_LINE_BYTES: rejected while framing (the server
        // never buffers it whole), surplus discarded, connection kept.
        vec![b'a'; mrtuner::coordinator::server::MAX_LINE_BYTES + 1024],
    ];
    for (i, g) in garbage.iter().enumerate() {
        stream.write_all(g).unwrap();
        stream.write_all(b"\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim())
            .unwrap_or_else(|e| panic!("case {i}: response not json ({e}): {line}"));
        assert_eq!(
            resp.get("ok"),
            Some(&Json::Bool(false)),
            "case {i}: expected structured error, got {line}"
        );
        assert!(
            resp.get("error").is_some(),
            "case {i}: error field missing: {line}"
        );
    }

    // The connection is still alive and serving.
    stream.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("pong"), "connection died after garbage: {line}");

    // Every reject was counted (the metrics report travels in stats).
    stream.write_all(b"{\"cmd\":\"stats\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(line.trim()).unwrap();
    let report = resp.get("report").and_then(Json::as_str).unwrap();
    assert!(
        report.contains(&format!("proto_errors: total={}", garbage.len())),
        "rejects not counted: {report}"
    );
    assert!(report.contains("bad_request="), "{report}");
    assert!(report.contains("wrong_version=1"), "{report}");

    drop(reader);
    drop(stream);
    shutdown();
}

// ---------------------------------------------------------------------
// Session lifecycle across reconnects: sessions are addressed by id and
// must survive the connection that opened them (per the CONN_IDLE doc).
// ---------------------------------------------------------------------

#[test]
fn stream_sessions_survive_reconnects() {
    let (addr, shutdown) = spawn_server(state_with_db());
    let cfg = JobConfig::new(4, 2, 10.0, 20.0);
    let series = raw_wave(0.2);

    // Connection 1: open the session, feed the first quarter, vanish
    // without closing anything (a crashed feeder).
    let session = {
        let mut c1 = MrtunerClient::connect(&addr.to_string()).unwrap();
        let opened = c1.stream_open(Some(&cfg), Some(64)).unwrap();
        assert_eq!(opened.candidates, 2);
        let fed = c1.stream_feed(opened.session, &series[..16]).unwrap();
        assert_eq!(fed.observed, 16);
        opened.session
        // c1 dropped here: TCP connection closes, session must live on.
    };

    // Connection 2: the restarted feeder picks the session up by id.
    let mut c2 = MrtunerClient::connect(&addr.to_string()).unwrap();
    let fed = c2.stream_feed(session, &series[16..48]).unwrap();
    assert_eq!(fed.observed, 48, "session lost its state across reconnect");
    let poll = c2.stream_poll(session, 2).unwrap();
    assert_eq!(poll.observed, 48);
    assert!(!poll.top.is_empty());
    assert_eq!(poll.top[0].app, "wordcount");

    // A third connection closes it and gets the exact final answer.
    let mut c3 = MrtunerClient::connect(&addr.to_string()).unwrap();
    c3.stream_feed(session, &series[48..]).unwrap();
    let closed = c3.stream_close(session).unwrap();
    assert_eq!(closed.observed, 64);
    assert_eq!(closed.final_match.unwrap().app, "wordcount");
    // Closed means gone, for every connection.
    let err = c2.stream_poll(session, 1).unwrap_err();
    assert_eq!(
        err.code(),
        Some(mrtuner::protocol::ErrorCode::UnknownSession),
        "{err}"
    );

    shutdown();
}

// ---------------------------------------------------------------------
// Client pipelining: many requests in flight, replies matched by id.
// ---------------------------------------------------------------------

#[test]
fn client_pipelines_and_matches_replies_by_id() {
    let (addr, shutdown) = spawn_server(state_with_db());
    let mut client = MrtunerClient::connect(&addr.to_string()).unwrap();
    let series = raw_wave(0.2);

    // Write three requests back-to-back before reading anything.
    let id_ping = client.send(&Request::Ping).unwrap();
    let id_knn = client
        .send(&Request::Knn {
            series: series.clone(),
            k: 1,
            config: None,
            allow_partial: false,
        })
        .unwrap();
    let id_apps = client.send(&Request::Apps).unwrap();
    assert!(id_ping < id_knn && id_knn < id_apps);

    // Collect them out of order: the pending map does the reordering.
    match client.recv(id_apps).unwrap() {
        mrtuner::protocol::Response::Apps(apps) => assert_eq!(apps.len(), 2),
        other => panic!("{other:?}"),
    }
    match client.recv(id_ping).unwrap() {
        mrtuner::protocol::Response::Pong => {}
        other => panic!("{other:?}"),
    }
    match client.recv(id_knn).unwrap() {
        mrtuner::protocol::Response::Knn(b) => {
            assert_eq!(b.neighbors.len(), 1);
            assert_eq!(b.neighbors[0].app, "wordcount");
            assert_eq!(b.neighbors[0].distance, 0.0);
        }
        other => panic!("{other:?}"),
    }
    // Unknown ids fail loudly instead of blocking.
    assert!(client.recv(9999).is_err());

    // k = 0 over the wire (v2 only): clean empty answer.
    let body = client.knn(&series, 0, None).unwrap();
    assert!(body.neighbors.is_empty());
    assert_eq!(body.stats, SearchStats::default());

    // k far beyond the database: clamped to everything, no phantom rows.
    let body = client.knn(&series, 100, None).unwrap();
    assert_eq!(body.neighbors.len(), 2);

    shutdown();
}

// ---------------------------------------------------------------------
// Error-surface coverage: every declared ErrorCode is reachable from a
// request line — five through the shard server's TCP loop, two through
// the router's line dispatch (the same decode/encode path its TCP
// front-end drives). No dead codes, no unreachable match arms.
// ---------------------------------------------------------------------

#[test]
fn every_error_code_is_reachable_from_wire_input() {
    use mrtuner::coordinator::router::{route_line, ShardRouter};
    use mrtuner::protocol::{ErrorCode, MAX_KNN_BATCH};
    use std::sync::{Arc, Mutex};

    let code_of = |line: String| -> ErrorCode {
        let resp = Json::parse(line.trim())
            .unwrap_or_else(|e| panic!("response not json ({e}): {line}"));
        assert_eq!(
            resp.get("ok"),
            Some(&Json::Bool(false)),
            "expected an error reply: {line}"
        );
        let code = resp
            .get("error")
            .and_then(|e| e.get("code"))
            .and_then(Json::as_str)
            .unwrap_or_else(|| panic!("no error.code in {line}"))
            .to_string();
        ErrorCode::parse(&code).unwrap_or_else(|| panic!("unparseable code {code}"))
    };
    let mut seen: Vec<ErrorCode> = Vec::new();

    // The five codes the shard server itself can answer, over real TCP.
    let (addr, shutdown) = spawn_server(state_with_db());
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let batch = vec!["[1,2,3,4]"; MAX_KNN_BATCH + 1].join(",");
    let cases = vec![
        // v2 envelope without an id: structurally broken request.
        (r#"{"v":2,"type":"ping"}"#.to_string(), ErrorCode::BadRequest),
        (r#"{"v":2,"id":1,"type":"gibberish"}"#.to_string(), ErrorCode::UnknownCommand),
        (
            r#"{"v":2,"id":2,"type":"stream_poll","session":777}"#.to_string(),
            ErrorCode::UnknownSession,
        ),
        (r#"{"v":99,"id":3,"type":"ping"}"#.to_string(), ErrorCode::WrongVersion),
        (
            format!(r#"{{"v":2,"id":4,"type":"knn_batch","queries":[{batch}],"k":1}}"#),
            ErrorCode::TooLarge,
        ),
    ];
    for (line, want) in &cases {
        stream.write_all(line.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        let got = code_of(resp);
        assert_eq!(got, *want, "wrong code for line: {line}");
        seen.push(got);
    }
    drop(reader);
    drop(stream);
    shutdown();

    // shard_unavailable: the router's shard dies between the handshake
    // and the query — the transport failure surfaces as a typed error
    // (after one idempotent replay), never a hang or a panic.
    let (shard_addr, shard_shutdown) = spawn_server(state_with_db());
    let metrics = Arc::new(Metrics::new());
    let addrs = vec![shard_addr.to_string()];
    let router = Mutex::new(ShardRouter::connect(&addrs, Arc::clone(&metrics)).unwrap());
    let tracer = mrtuner::trace::TraceHandle::disabled();

    // deadline_exceeded: a zero-millisecond deadline is spent before the
    // first shard wait, so the budget check answers — deterministically,
    // while the shard is still alive and well.
    let resp = route_line(
        r#"{"v":2,"id":7,"type":"knn","series":[1,2,3,4],"k":1,"deadline_ms":0}"#,
        &router,
        &metrics,
        &tracer,
    );
    let got = code_of(resp.to_string());
    assert_eq!(got, ErrorCode::DeadlineExceeded);
    seen.push(got);

    shard_shutdown();
    let resp = route_line(
        r#"{"v":2,"id":5,"type":"knn","series":[1,2,3,4],"k":1}"#,
        &router,
        &metrics,
        &tracer,
    );
    let got = code_of(resp.to_string());
    assert_eq!(got, ErrorCode::ShardUnavailable);
    seen.push(got);

    // internal: a panic while the router lock was held poisons it; later
    // requests get a typed reply instead of a cascading panic.
    let solo = ShardRouter::connect(&[], Arc::clone(&metrics)).unwrap();
    let poisoned = Arc::new(Mutex::new(solo));
    let clone = Arc::clone(&poisoned);
    let _ = std::thread::spawn(move || {
        let _guard = clone.lock().unwrap();
        panic!("poison the router lock");
    })
    .join();
    let resp = route_line(r#"{"v":2,"id":6,"type":"ping"}"#, &poisoned, &metrics, &tracer);
    let got = code_of(resp.to_string());
    assert_eq!(got, ErrorCode::Internal);
    seen.push(got);

    // The surface is complete: every declared code produced, once each.
    for code in ErrorCode::ALL {
        assert!(seen.contains(&code), "{} never produced", code.as_str());
    }
    assert_eq!(seen.len(), ErrorCode::ALL.len(), "duplicate coverage: {seen:?}");
}

// ---------------------------------------------------------------------
// Flight recorder over the wire: `trace_dump` returns the ring as a
// Chrome-loadable document without consuming it, and the metrics
// snapshot carries the trace counters — all through real TCP.
// ---------------------------------------------------------------------

#[test]
fn trace_dump_and_trace_counters_round_trip_over_tcp() {
    use mrtuner::trace::{FlightRecorder, TraceHandle, Tracker, VirtualClock};
    use std::sync::Arc;

    let recorder = Arc::new(FlightRecorder::new(64));
    let mut state = state_with_db();
    state.tracer = TraceHandle::with_clock(
        Arc::clone(&recorder) as Arc<dyn Tracker>,
        Arc::new(VirtualClock::new(10)),
    );
    state.recorder = Some(Arc::clone(&recorder));
    let (addr, shutdown) = spawn_server(state);

    let mut client = MrtunerClient::connect(&addr.to_string()).unwrap();
    let body = client.knn(&raw_wave(0.2), 1, None).unwrap();
    assert_eq!(body.neighbors.len(), 1);

    // Two dumps of the same ring: point-in-time copies, the knn request's
    // tree present and Chrome-shaped in both (dumping doesn't drain).
    for round in 0..2 {
        let dump = client.trace_dump().unwrap();
        assert!(
            dump.get("spans").and_then(Json::as_u64).unwrap() >= 1,
            "round {round}: empty ring: {dump}"
        );
        assert_eq!(dump.get("dropped").and_then(Json::as_u64), Some(0));
        let doc = dump.get("trace").unwrap();
        assert_eq!(doc.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert!(
            events
                .iter()
                .any(|e| e.get("name").and_then(Json::as_str) == Some("request")),
            "round {round}: no request span in {doc}"
        );
    }

    // The snapshot's trace block travels too. Four recorded roots by the
    // time it is taken (knn, both dumps, and the metrics request itself —
    // roots are counted at decode, before dispatch), two recorder dumps.
    let m = client.metrics().unwrap();
    let trace = m.get("trace").expect("pinned trace block");
    assert_eq!(trace.get("spans_recorded").and_then(Json::as_u64), Some(4), "{m}");
    assert_eq!(trace.get("spans_sampled_out").and_then(Json::as_u64), Some(0), "{m}");
    assert_eq!(trace.get("recorder_dumps").and_then(Json::as_u64), Some(2), "{m}");
    assert_eq!(trace.get("recorder_dropped").and_then(Json::as_u64), Some(0), "{m}");

    drop(client);
    shutdown();
}
