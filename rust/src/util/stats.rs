//! Descriptive statistics shared by the similarity pipeline, the simulator
//! cost models and the bench harness.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0.0 for len < 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile by linear interpolation on a *sorted copy* (q in [0, 100]).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite values"));
    let rank = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Pearson correlation coefficient between equal-length series.
/// Returns 0.0 when either side is constant (no linear relation defined).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len(), "pearson: length mismatch");
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        cov += dx * dy;
        vx += dx * dx;
        vy += dy * dy;
    }
    if vx <= 0.0 || vy <= 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Online mean/variance accumulator (Welford). Used by the metrics module so
/// the serve loop never stores full sample vectors.
#[derive(Debug, Clone)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for Welford {
    fn default() -> Self {
        Welford::new()
    }
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((variance(&xs) - 4.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_and_inverse() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0).collect();
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let zs: Vec<f64> = xs.iter().map(|x| -x).collect();
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_is_zero() {
        let xs = [1.0, 1.0, 1.0];
        let ys = [1.0, 2.0, 3.0];
        assert_eq!(pearson(&xs, &ys), 0.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [0.5, 1.5, -2.0, 8.0, 3.25];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.variance() - variance(&xs)).abs() < 1e-12);
        assert_eq!(w.min(), -2.0);
        assert_eq!(w.max(), 8.0);
        assert_eq!(w.count(), 5);
    }
}
