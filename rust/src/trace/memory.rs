//! [`InMemoryTracker`]: records the whole span tree in memory for tests,
//! CI assertions and post-hoc inspection.

use super::{SpanId, Tracker};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// One recorded span. `end_ns == 0` means the span is still open (or was
/// leaked); events and notes are in arrival order.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRecord {
    pub id: SpanId,
    pub name: &'static str,
    /// Enclosing local span (0 for roots).
    pub parent: SpanId,
    /// Span id received over the wire (0 if none) — links this tree under
    /// a span recorded by a *different* tracker on the sending peer.
    pub remote_parent: SpanId,
    pub start_ns: u64,
    pub end_ns: u64,
    pub events: Vec<(&'static str, u64)>,
    pub notes: Vec<(&'static str, String)>,
}

/// Span sink keeping every record; query helpers reconstruct the tree.
#[derive(Debug, Default)]
pub struct InMemoryTracker {
    next: AtomicU64,
    spans: Mutex<Vec<SpanRecord>>,
}

impl InMemoryTracker {
    pub fn new() -> InMemoryTracker {
        InMemoryTracker::default()
    }

    /// Snapshot of every span recorded so far, in begin order.
    pub fn spans(&self) -> Vec<SpanRecord> {
        self.guard().clone()
    }

    /// Recorded roots (spans with no local parent), in begin order.
    pub fn roots(&self) -> Vec<SpanRecord> {
        self.guard().iter().filter(|s| s.parent == 0).cloned().collect()
    }

    /// Direct children of `parent`, in begin order.
    pub fn children_of(&self, parent: SpanId) -> Vec<SpanRecord> {
        self.guard().iter().filter(|s| s.parent == parent).cloned().collect()
    }

    /// Every span named `name`, in begin order.
    pub fn find(&self, name: &str) -> Vec<SpanRecord> {
        self.guard().iter().filter(|s| s.name == name).cloned().collect()
    }

    /// Drop all recorded spans (the id counter keeps running).
    pub fn clear(&self) {
        self.guard().clear();
    }

    fn guard(&self) -> std::sync::MutexGuard<'_, Vec<SpanRecord>> {
        // A panic while holding this lock can only come from Vec growth
        // failing; the poisoned data is still just records, so recover it.
        self.spans.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn with_span(&self, id: SpanId, f: impl FnOnce(&mut SpanRecord)) {
        let mut spans = self.guard();
        if let Some(s) = spans.iter_mut().rev().find(|s| s.id == id) {
            f(s);
        }
    }
}

impl Tracker for InMemoryTracker {
    fn is_enabled(&self) -> bool {
        true
    }

    fn begin(
        &self,
        name: &'static str,
        parent: SpanId,
        remote_parent: SpanId,
        now_ns: u64,
    ) -> SpanId {
        // relaxed: monotone id counter — uniqueness is all that matters,
        // no other memory is published through it.
        let id = self.next.fetch_add(1, Ordering::Relaxed) + 1;
        self.guard().push(SpanRecord {
            id,
            name,
            parent,
            remote_parent,
            start_ns: now_ns,
            end_ns: 0,
            events: Vec::new(),
            notes: Vec::new(),
        });
        id
    }

    fn end(&self, span: SpanId, now_ns: u64) {
        self.with_span(span, |s| s.end_ns = now_ns);
    }

    fn event(&self, span: SpanId, name: &'static str, value: u64, _now_ns: u64) {
        self.with_span(span, |s| s.events.push((name, value)));
    }

    fn note(&self, span: SpanId, key: &'static str, text: &str, _now_ns: u64) {
        self.with_span(span, |s| s.notes.push((key, text.to_string())));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_tree_shape_and_payloads() {
        let t = InMemoryTracker::new();
        let root = t.begin("request", 0, 42, 100);
        let child = t.begin("handle", root, 0, 110);
        t.event(child, "queries", 8, 111);
        t.note(child, "config", "M=4,R=2", 112);
        t.end(child, 120);
        t.end(root, 130);

        assert_eq!(t.spans().len(), 2);
        let roots = t.roots();
        assert_eq!(roots.len(), 1);
        assert_eq!(roots[0].name, "request");
        assert_eq!(roots[0].remote_parent, 42);
        assert_eq!((roots[0].start_ns, roots[0].end_ns), (100, 130));

        let kids = t.children_of(root);
        assert_eq!(kids.len(), 1);
        assert_eq!(kids[0].events, vec![("queries", 8)]);
        assert_eq!(kids[0].notes, vec![("config", "M=4,R=2".to_string())]);

        assert_eq!(t.find("handle").len(), 1);
        assert!(t.find("missing").is_empty());
    }

    #[test]
    fn ids_are_unique_and_clear_keeps_counting() {
        let t = InMemoryTracker::new();
        let a = t.begin("a", 0, 0, 1);
        let b = t.begin("b", 0, 0, 2);
        assert_ne!(a, b);
        t.clear();
        assert!(t.spans().is_empty());
        let c = t.begin("c", 0, 0, 3);
        assert!(c > b, "id counter survives clear");
    }

    #[test]
    fn end_on_unknown_id_is_a_no_op() {
        let t = InMemoryTracker::new();
        t.end(999, 5);
        t.event(999, "x", 1, 5);
        assert!(t.spans().is_empty());
    }
}
