//! The reference database: profiled CPU-utilization patterns keyed by
//! (application, configuration set), plus known-optimal configurations.

pub mod profile;
pub mod store;
