"""L1 Pallas kernel: Chebyshev de-noising + magnitude normalization.

The paper's pre-processing (6th-order type-I Chebyshev low-pass, then
min-max normalization to [0,1]) as one kernel. The IIR recurrence is
sequential in textbook form; here each biquad's 2-state Direct Form II
transposed recurrence

    z_n = A z_{n-1} + c_n,   A = [[-a1, 1], [-a2, 0]],
    c_n = [(b1 - a1 b0) x_n, (b2 - a2 b0) x_n],  y_n = b0 x_n + s1_{n-1}

is an *affine* recurrence, closed under composition, so the whole series is
one ``associative_scan`` over ``(A, c)`` pairs per biquad — three log-depth
scans for the 6th-order cascade instead of an L-step loop. Normalization
masks to the valid prefix ``[0, n)`` and zeroes the padding.

Filter coefficients come from ``compile.filters`` (scipy-pinned) and are
baked into the HLO at trace time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .. import filters


def _affine_combine(left, right):
    """Composition of z -> A z + c affine maps (right applied after left)."""
    a1, c1 = left
    a2, c2 = right
    return a2 @ a1, a2 @ c1 + c2


def _biquad_scan(x, b0, b1, b2, a1, a2):
    """Run one biquad over ``x`` via an affine associative scan.

    ``A`` is assembled from the traced coefficient scalars (pallas kernels
    may not capture array constants), hence the ``a1 * 0 + 1`` dance.
    """
    L = x.shape[0]
    one = a1 * 0.0 + 1.0
    zero = a1 * 0.0
    A = jnp.stack([jnp.stack([-a1, one]), jnp.stack([-a2, zero])])
    As = jnp.broadcast_to(A, (L, 2, 2))
    cs = jnp.stack([(b1 - a1 * b0) * x, (b2 - a2 * b0) * x], axis=-1)[..., None]
    _, zs = jax.lax.associative_scan(_affine_combine, (As, cs))
    s1 = zs[:, 0, 0]
    # y_n uses the *previous* sample's state.
    s1_prev = jnp.concatenate([zero[None], s1[:-1]])
    return b0 * x + s1_prev


def _preprocess_kernel(x_ref, n_ref, sos_ref, out_ref):
    x = x_ref[...]
    n = n_ref[0]
    sos = sos_ref[...]
    L = x.shape[0]
    y = x
    for k in range(sos.shape[0]):
        y = _biquad_scan(y, sos[k, 0], sos[k, 1], sos[k, 2], sos[k, 4], sos[k, 5])
    mask = jnp.arange(L) < n
    lo = jnp.min(jnp.where(mask, y, jnp.float32(1e30)))
    hi = jnp.max(jnp.where(mask, y, jnp.float32(-1e30)))
    span = hi - lo
    safe = jnp.where(span > 0, span, jnp.float32(1.0))
    norm = jnp.where(span > 0, (y - lo) / safe, jnp.float32(0.0))
    out_ref[...] = jnp.where(mask, norm, jnp.float32(0.0)).astype(jnp.float32)


def preprocess(x, n, sos=None):
    """Filter + normalize a padded series.

    Args:
      x: f32[L] raw series (pad beyond ``n`` ignored).
      n: i32[1] valid length.
      sos: optional (3, 6) float second-order sections; defaults to the
        paper's 6th-order 0.5 dB / 0.1-Nyquist design.

    Returns:
      f32[L]: de-noised series normalized into [0,1]; padding zeroed.
    """
    sos = np.asarray(filters.PAPER_SOS if sos is None else sos, dtype=np.float32)
    L = x.shape[0]
    x = x.astype(jnp.float32)
    n = n.astype(jnp.int32)
    return pl.pallas_call(
        _preprocess_kernel,
        out_shape=jax.ShapeDtypeStruct((L,), jnp.float32),
        interpret=True,
    )(x, n, jnp.asarray(sos))
